//! Kernel block evaluation K(X_I, Y_J) over dense or CSR rows.
//!
//! Uses the ‖x‖² + ‖y‖² − 2 xᵀy expansion: for dense operands the xᵀy
//! term is a gemm (the MXU-friendly structure the L1 Pallas kernel also
//! uses); for CSR operands it is a sparse×dense gather or sparse×sparse
//! merge accumulation — exactly the term where sparsity pays, since the
//! norm and exp parts are O(mn) regardless. This native path is the
//! fallback and correctness oracle for the PJRT-executed artifact in
//! [`crate::runtime`], and the reference implementation behind
//! [`crate::compute::CpuBackend`].
//!
//! The `*_pts` functions are the data-plane entry points; the `Mat`
//! variants are the dense arm of the same implementation (the `_pts`
//! dense×dense case delegates straight to them), so dense results are
//! bit-for-bit independent of which entry point is used. Serial and
//! banded-parallel variants share one per-row evaluation core
//! ([`finish_row`] / [`fill_row_pts`]) and one row-scatter helper
//! ([`scatter_rows`]) holding the module's single `unsafe` site.

use crate::data::sparse::Points;
use crate::kernel::Kernel;
use crate::linalg::blas::{self, Trans};
use crate::linalg::Mat;
use crate::util::threadpool;

/// Squared norms of the rows of X (dense).
pub fn self_norms(x: &Mat) -> Vec<f64> {
    (0..x.rows()).map(|i| blas::dot(x.row(i), x.row(i))).collect()
}

/// Finish one gemm row in place: g[j] = K from (nxi, ny[j], xᵀy).
/// The shared core of every serial and parallel finishing loop.
#[inline]
fn finish_row(k: &Kernel, nxi: f64, ny: &[f64], row: &mut [f64]) {
    for (j, v) in row.iter_mut().enumerate() {
        *v = k.eval_from_parts(nxi, ny[j], *v);
    }
}

/// Evaluate row i of a `Points` block into `row`: xᵀy accumulation
/// (gather/merge via [`Points::row_dots`]) then the norm expansion.
/// Both the serial and the banded-parallel sparse paths run exactly
/// this, so they are bitwise-equal by construction.
#[inline]
fn fill_row_pts(
    k: &Kernel,
    x: &Points,
    nx: &[f64],
    y: &Points,
    ny: &[f64],
    i: usize,
    row: &mut [f64],
) {
    x.row_dots(i, y, row);
    finish_row(k, nx[i], ny, row);
}

/// Band the rows of `g` across threads and fill each with `fill(i, row)`.
/// The single unsafe scatter of this module — both parallel block
/// variants funnel through it.
fn scatter_rows(threads: usize, g: &mut Mat, fill: impl Fn(usize, &mut [f64]) + Sync) {
    let (m, n) = g.shape();
    let data = g.data_mut();
    let cells = threadpool::as_send_cells(data);
    threadpool::parallel_for(threads, m, 16, |i| {
        // SAFETY: row ranges i*n..(i+1)*n are disjoint per index i, and
        // each index runs exactly once (slice keeps whole-buffer
        // provenance, unlike a raw reborrow of a single-element pointer).
        let row = unsafe { cells.slice(i * n, n) };
        fill(i, row);
    });
}

/// K(X, Y): rows of X against rows of Y. O(m n f) via gemm.
pub fn kernel_block(k: &Kernel, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols(), y.cols(), "feature dimension mismatch");
    let nx = self_norms(x);
    let ny = self_norms(y);
    kernel_block_with_norms(k, x, &nx, y, &ny)
}

/// Same with caller-provided squared row norms (avoids recomputation in
/// tiled prediction loops).
pub fn kernel_block_with_norms(k: &Kernel, x: &Mat, nx: &[f64], y: &Mat, ny: &[f64]) -> Mat {
    let mut g = blas::matmul(x, Trans::No, y, Trans::Yes);
    finish_block(k, &mut g, nx, ny);
    g
}

/// Parallel variant, banding the rows of X across threads.
pub fn kernel_block_par(threads: usize, k: &Kernel, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols(), y.cols(), "feature dimension mismatch");
    let nx = self_norms(x);
    let ny = self_norms(y);
    let mut g = blas::matmul_par(threads, x, Trans::No, y, Trans::Yes);
    scatter_rows(threads, &mut g, |i, row| finish_row(k, nx[i], ny, row));
    g
}

fn finish_block(k: &Kernel, g: &mut Mat, nx: &[f64], ny: &[f64]) {
    let (m, n) = g.shape();
    assert_eq!(nx.len(), m);
    assert_eq!(ny.len(), n);
    for i in 0..m {
        finish_row(k, nx[i], ny, g.row_mut(i));
    }
}

/// Single kernel row K(x_i, Y) as a vector (SMO hot path, dense).
pub fn kernel_row(k: &Kernel, xi: &[f64], ni: f64, y: &Mat, ny: &[f64], out: &mut [f64]) {
    assert_eq!(y.rows(), out.len());
    for j in 0..y.rows() {
        let ab = blas::dot(xi, y.row(j));
        out[j] = k.eval_from_parts(ni, ny[j], ab);
    }
}

// ---------------------------------------------------------------------
// Representation-generic ([`Points`]) entry points
// ---------------------------------------------------------------------

/// Squared norms of the rows of a [`Points`] container.
pub fn self_norms_pts(x: &Points) -> Vec<f64> {
    x.self_norms()
}

/// K(X, Y) over any dense/CSR operand pairing. Dense×dense delegates to
/// the gemm path; any sparse operand routes the xᵀy term through
/// sparse×dense / sparse×sparse row accumulation.
pub fn kernel_block_pts(k: &Kernel, x: &Points, y: &Points) -> Mat {
    assert_eq!(x.cols(), y.cols(), "feature dimension mismatch");
    let nx = x.self_norms();
    let ny = y.self_norms();
    kernel_block_pts_with_norms(k, x, &nx, y, &ny)
}

/// [`kernel_block_pts`] with caller-provided squared row norms.
pub fn kernel_block_pts_with_norms(
    k: &Kernel,
    x: &Points,
    nx: &[f64],
    y: &Points,
    ny: &[f64],
) -> Mat {
    if let (Points::Dense(xm), Points::Dense(ym)) = (x, y) {
        return kernel_block_with_norms(k, xm, nx, ym, ny);
    }
    let m = x.rows();
    let n = y.rows();
    assert_eq!(nx.len(), m);
    assert_eq!(ny.len(), n);
    let mut g = Mat::zeros(m, n);
    for i in 0..m {
        fill_row_pts(k, x, nx, y, ny, i, g.row_mut(i));
    }
    g
}

/// Parallel [`kernel_block_pts`], banding the rows of X across threads.
pub fn kernel_block_pts_par(threads: usize, k: &Kernel, x: &Points, y: &Points) -> Mat {
    if let (Points::Dense(xm), Points::Dense(ym)) = (x, y) {
        return kernel_block_par(threads, k, xm, ym);
    }
    assert_eq!(x.cols(), y.cols(), "feature dimension mismatch");
    let nx = x.self_norms();
    let ny = y.self_norms();
    let mut g = Mat::zeros(x.rows(), y.rows());
    scatter_rows(threads, &mut g, |i, row| fill_row_pts(k, x, &nx, y, &ny, i, row));
    g
}

/// Single kernel row K(x_i, Y) over any representation pairing
/// (SMO hot path).
pub fn kernel_row_pts(
    k: &Kernel,
    x: &Points,
    i: usize,
    ni: f64,
    y: &Points,
    ny: &[f64],
    out: &mut [f64],
) {
    assert_eq!(y.rows(), out.len());
    x.row_dots(i, y, out);
    for (j, v) in out.iter_mut().enumerate() {
        *v = k.eval_from_parts(ni, ny[j], *v);
    }
}

/// K(x_i, t) for a single dense point `t` — the pointwise model
/// evaluation ([`crate::svm::SvmModel::decision_one`]). The dense arm is
/// the original `Kernel::eval` on slices; the sparse arm goes through
/// the norm expansion.
pub fn eval_one(k: &Kernel, x: &Points, i: usize, t: &[f64]) -> f64 {
    match x {
        Points::Dense(m) => k.eval(m.row(i), t),
        Points::Sparse(_) => {
            let ni = x.dot_row(i, x, i);
            let nt = blas::dot(t, t);
            let ab = x.dot_dense_vec(i, t);
            k.eval_from_parts(ni, nt, ab)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit;
    use crate::util::testkit::random_csr;

    fn naive_block(k: &Kernel, x: &Mat, y: &Mat) -> Mat {
        Mat::from_fn(x.rows(), y.rows(), |i, j| k.eval(x.row(i), y.row(j)))
    }

    #[test]
    fn block_matches_pointwise_eval() {
        testkit::check("kernel-block", 10, |rng, _| {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(30);
            let f = 1 + rng.below(20);
            let x = Mat::gauss(m, f, rng);
            let y = Mat::gauss(n, f, rng);
            for k in [Kernel::Gaussian { h: 0.8 }, Kernel::Polynomial { degree: 2, c: 1.0 }, Kernel::Linear] {
                let got = kernel_block(&k, &x, &y);
                let want = naive_block(&k, &x, &y);
                testkit::assert_allclose(got.data(), want.data(), 1e-11);
            }
        });
    }

    #[test]
    fn par_matches_serial() {
        let mut rng = Rng::new(6);
        let x = Mat::gauss(200, 10, &mut rng);
        let y = Mat::gauss(150, 10, &mut rng);
        let k = Kernel::Gaussian { h: 1.3 };
        let serial = kernel_block(&k, &x, &y);
        let par = kernel_block_par(4, &k, &x, &y);
        testkit::assert_allclose(par.data(), serial.data(), 1e-13);
    }

    #[test]
    fn miri_kernel_block_par_row_scatter() {
        // Tiny instance for the Miri lane: with 40 rows and chunk 16 the
        // scatter spans multiple chunks across real worker threads, and
        // the row-banded writes must match the serial block.
        let mut rng = Rng::new(11);
        let x = Mat::gauss(40, 3, &mut rng);
        let y = Mat::gauss(7, 3, &mut rng);
        let k = Kernel::Gaussian { h: 1.0 };
        let serial = kernel_block(&k, &x, &y);
        let par = kernel_block_par(2, &k, &x, &y);
        testkit::assert_allclose(par.data(), serial.data(), 1e-13);
    }

    #[test]
    fn kernel_row_matches_block() {
        let mut rng = Rng::new(7);
        let x = Mat::gauss(5, 4, &mut rng);
        let y = Mat::gauss(9, 4, &mut rng);
        let k = Kernel::Gaussian { h: 0.5 };
        let block = kernel_block(&k, &x, &y);
        let ny = self_norms(&y);
        let mut row = vec![0.0; 9];
        for i in 0..5 {
            let ni = crate::linalg::dot(x.row(i), x.row(i));
            kernel_row(&k, x.row(i), ni, &y, &ny, &mut row);
            testkit::assert_allclose(&row, block.row(i), 1e-12);
        }
    }

    #[test]
    fn gaussian_diag_is_one() {
        let mut rng = Rng::new(8);
        let x = Mat::gauss(12, 6, &mut rng);
        let g = kernel_block(&Kernel::Gaussian { h: 2.0 }, &x, &x);
        for i in 0..12 {
            testkit::assert_close(g[(i, i)], 1.0, 1e-12);
        }
    }

    #[test]
    fn sparse_block_matches_dense_all_pairings() {
        testkit::check("sparse-kernel-block", 8, |rng, _| {
            let m = 1 + rng.below(20);
            let n = 1 + rng.below(20);
            let f = 2 + rng.below(40);
            let xs = random_csr(m, f, 0.3, rng);
            let ys = random_csr(n, f, 0.3, rng);
            let xd = Points::Dense(xs.to_dense());
            let yd = Points::Dense(ys.to_dense());
            let xs = Points::Sparse(xs);
            let ys = Points::Sparse(ys);
            for k in [
                Kernel::Gaussian { h: 0.8 },
                Kernel::Polynomial { degree: 2, c: 1.0 },
                Kernel::Linear,
            ] {
                let want = kernel_block_pts(&k, &xd, &yd);
                for (a, b) in [(&xs, &ys), (&xs, &yd), (&xd, &ys)] {
                    let got = kernel_block_pts(&k, a, b);
                    testkit::assert_allclose(got.data(), want.data(), 1e-12);
                }
            }
        });
    }

    #[test]
    fn sparse_par_matches_serial() {
        let mut rng = Rng::new(9);
        let xs = random_csr(90, 50, 0.15, &mut rng);
        let ys = random_csr(70, 50, 0.15, &mut rng);
        let (x, y) = (Points::Sparse(xs), Points::Sparse(ys));
        let k = Kernel::Gaussian { h: 1.1 };
        let serial = kernel_block_pts(&k, &x, &y);
        let par = kernel_block_pts_par(3, &k, &x, &y);
        assert_eq!(serial, par, "sparse parallel block must be bitwise equal");
    }

    #[test]
    fn sparse_kernel_row_and_eval_one_match_block() {
        let mut rng = Rng::new(10);
        let xs = random_csr(6, 25, 0.3, &mut rng);
        let ys = random_csr(8, 25, 0.3, &mut rng);
        let yd = ys.to_dense();
        let (x, y) = (Points::Sparse(xs), Points::Sparse(ys));
        let k = Kernel::Gaussian { h: 0.7 };
        let block = kernel_block_pts(&k, &x, &y);
        let ny = y.self_norms();
        let nx = x.self_norms();
        let mut row = vec![0.0; 8];
        for i in 0..6 {
            kernel_row_pts(&k, &x, i, nx[i], &y, &ny, &mut row);
            testkit::assert_allclose(&row, block.row(i), 1e-12);
            for j in 0..8 {
                testkit::assert_close(eval_one(&k, &x, i, yd.row(j)), block[(i, j)], 1e-12);
            }
        }
    }
}
