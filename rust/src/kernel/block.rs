//! Dense kernel block evaluation K(X_I, Y_J).
//!
//! Uses the ‖x‖² + ‖y‖² − 2 xᵀy expansion: the xᵀy term is a gemm (the
//! MXU-friendly structure the L1 Pallas kernel also uses), the rest is a
//! rank-1 broadcast + elementwise exp. This native path is the fallback
//! and correctness oracle for the PJRT-executed artifact in
//! [`crate::runtime`].

use crate::kernel::Kernel;
use crate::linalg::blas::{self, Trans};
use crate::linalg::Mat;
use crate::util::threadpool;

/// Squared norms of the rows of X.
pub fn self_norms(x: &Mat) -> Vec<f64> {
    (0..x.rows()).map(|i| blas::dot(x.row(i), x.row(i))).collect()
}

/// K(X, Y): rows of X against rows of Y. O(m n f) via gemm.
pub fn kernel_block(k: &Kernel, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols(), y.cols(), "feature dimension mismatch");
    let nx = self_norms(x);
    let ny = self_norms(y);
    kernel_block_with_norms(k, x, &nx, y, &ny)
}

/// Same with caller-provided squared row norms (avoids recomputation in
/// tiled prediction loops).
pub fn kernel_block_with_norms(k: &Kernel, x: &Mat, nx: &[f64], y: &Mat, ny: &[f64]) -> Mat {
    let mut g = blas::matmul(x, Trans::No, y, Trans::Yes);
    finish_block(k, &mut g, nx, ny);
    g
}

/// Parallel variant, banding the rows of X across threads.
pub fn kernel_block_par(threads: usize, k: &Kernel, x: &Mat, y: &Mat) -> Mat {
    assert_eq!(x.cols(), y.cols(), "feature dimension mismatch");
    let nx = self_norms(x);
    let ny = self_norms(y);
    let mut g = blas::matmul_par(threads, x, Trans::No, y, Trans::Yes);
    // finish rows in parallel
    let m = g.rows();
    let n = g.cols();
    let data = g.data_mut();
    let cells = threadpool::as_send_cells(data);
    threadpool::parallel_for(threads, m, 16, |i| {
        // SAFETY: row bands are disjoint per index i.
        let row = unsafe { std::slice::from_raw_parts_mut(cells.get(i * n), n) };
        for (j, v) in row.iter_mut().enumerate() {
            *v = k.eval_from_parts(nx[i], ny[j], *v);
        }
    });
    g
}

fn finish_block(k: &Kernel, g: &mut Mat, nx: &[f64], ny: &[f64]) {
    let (m, n) = g.shape();
    assert_eq!(nx.len(), m);
    assert_eq!(ny.len(), n);
    for i in 0..m {
        let row = g.row_mut(i);
        let nxi = nx[i];
        for (j, v) in row.iter_mut().enumerate() {
            *v = k.eval_from_parts(nxi, ny[j], *v);
        }
    }
}

/// Single kernel row K(x_i, Y) as a vector (SMO hot path).
pub fn kernel_row(k: &Kernel, xi: &[f64], ni: f64, y: &Mat, ny: &[f64], out: &mut [f64]) {
    assert_eq!(y.rows(), out.len());
    for j in 0..y.rows() {
        let ab = blas::dot(xi, y.row(j));
        out[j] = k.eval_from_parts(ni, ny[j], ab);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    fn naive_block(k: &Kernel, x: &Mat, y: &Mat) -> Mat {
        Mat::from_fn(x.rows(), y.rows(), |i, j| k.eval(x.row(i), y.row(j)))
    }

    #[test]
    fn block_matches_pointwise_eval() {
        testkit::check("kernel-block", 10, |rng, _| {
            let m = 1 + rng.below(30);
            let n = 1 + rng.below(30);
            let f = 1 + rng.below(20);
            let x = Mat::gauss(m, f, rng);
            let y = Mat::gauss(n, f, rng);
            for k in [Kernel::Gaussian { h: 0.8 }, Kernel::Polynomial { degree: 2, c: 1.0 }, Kernel::Linear] {
                let got = kernel_block(&k, &x, &y);
                let want = naive_block(&k, &x, &y);
                testkit::assert_allclose(got.data(), want.data(), 1e-11);
            }
        });
    }

    #[test]
    fn par_matches_serial() {
        let mut rng = Rng::new(6);
        let x = Mat::gauss(200, 10, &mut rng);
        let y = Mat::gauss(150, 10, &mut rng);
        let k = Kernel::Gaussian { h: 1.3 };
        let serial = kernel_block(&k, &x, &y);
        let par = kernel_block_par(4, &k, &x, &y);
        testkit::assert_allclose(par.data(), serial.data(), 1e-13);
    }

    #[test]
    fn kernel_row_matches_block() {
        let mut rng = Rng::new(7);
        let x = Mat::gauss(5, 4, &mut rng);
        let y = Mat::gauss(9, 4, &mut rng);
        let k = Kernel::Gaussian { h: 0.5 };
        let block = kernel_block(&k, &x, &y);
        let ny = self_norms(&y);
        let mut row = vec![0.0; 9];
        for i in 0..5 {
            let ni = crate::linalg::dot(x.row(i), x.row(i));
            kernel_row(&k, x.row(i), ni, &y, &ny, &mut row);
            testkit::assert_allclose(&row, block.row(i), 1e-12);
        }
    }

    #[test]
    fn gaussian_diag_is_one() {
        let mut rng = Rng::new(8);
        let x = Mat::gauss(12, 6, &mut rng);
        let g = kernel_block(&Kernel::Gaussian { h: 2.0 }, &x, &x);
        for i in 0..12 {
            testkit::assert_close(g[(i, i)], 1.0, 1e-12);
        }
    }
}
