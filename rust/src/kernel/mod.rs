//! Positive-definite kernels and block evaluation.
//!
//! The Gaussian kernel K(x, y) = exp(−‖x−y‖²/(2h²)) is the paper's
//! kernel; polynomial and linear are included for API completeness and
//! for tests. Block evaluation is the dense hot-spot of the whole system
//! (compression probes, SMO cache rows, prediction) — it is computed via
//! the ‖x‖² + ‖y‖² − 2xᵀy expansion so the inner work is a gemm, which is
//! exactly the structure the L1 Pallas kernel mirrors on the MXU.

pub mod block;

pub use block::{
    eval_one, kernel_block, kernel_block_par, kernel_block_pts, kernel_block_pts_par,
    kernel_block_pts_with_norms, kernel_row, kernel_row_pts, self_norms, self_norms_pts,
};

use crate::data::sparse::Points;
use crate::linalg::Mat;

/// A positive-definite kernel function.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Kernel {
    /// exp(−‖x−y‖² / (2h²)) — the paper's kernel; `h` is the width.
    Gaussian { h: f64 },
    /// (xᵀy + c)^degree.
    Polynomial { degree: u32, c: f64 },
    /// xᵀy.
    Linear,
}

impl Kernel {
    /// γ = 1/(2h²) for the Gaussian (the scalar the AOT artifact takes).
    pub fn gamma(&self) -> f64 {
        match self {
            Kernel::Gaussian { h } => 1.0 / (2.0 * h * h),
            _ => 0.0,
        }
    }

    /// Evaluate K(a, b) for two points.
    pub fn eval(&self, a: &[f64], b: &[f64]) -> f64 {
        match *self {
            Kernel::Gaussian { .. } => {
                let d2 = crate::linalg::blas::dist2(a, b);
                crate::linalg::blas::exp_neg(-self.gamma() * d2)
            }
            Kernel::Polynomial { degree, c } => {
                (crate::linalg::dot(a, b) + c).powi(degree as i32)
            }
            Kernel::Linear => crate::linalg::dot(a, b),
        }
    }

    /// Evaluate from precomputed squared norms and the inner product —
    /// the form used inside gemm-based block evaluation.
    #[inline]
    pub fn eval_from_parts(&self, na2: f64, nb2: f64, ab: f64) -> f64 {
        match *self {
            Kernel::Gaussian { .. } => {
                let d2 = (na2 + nb2 - 2.0 * ab).max(0.0);
                crate::linalg::blas::exp_neg(-self.gamma() * d2)
            }
            Kernel::Polynomial { degree, c } => (ab + c).powi(degree as i32),
            Kernel::Linear => ab,
        }
    }

    /// Full dense kernel matrix K(X, X) — small problems / tests only.
    /// Accepts dense or CSR points; the result is always dense.
    pub fn gram(&self, x: &Points) -> Mat {
        kernel_block_pts(self, x, x)
    }

    /// Short id for reports ("rbf(h=1)" etc.).
    pub fn label(&self) -> String {
        match *self {
            Kernel::Gaussian { h } => format!("rbf(h={h})"),
            Kernel::Polynomial { degree, c } => format!("poly(d={degree},c={c})"),
            Kernel::Linear => "linear".to_string(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;
    use crate::util::testkit;

    #[test]
    fn gaussian_basic_identities() {
        let k = Kernel::Gaussian { h: 1.0 };
        let a = [1.0, 2.0];
        assert!((k.eval(&a, &a) - 1.0).abs() < 1e-15, "K(x,x) = 1");
        let b = [3.0, 4.0];
        let want = (-8.0f64 / 2.0).exp(); // d² = 8, 2h² = 2
        // exp_neg fast path is accurate to ~5e-9 relative
        assert!((k.eval(&a, &b) - want).abs() < 1e-9);
        assert!((k.gamma() - 0.5).abs() < 1e-15);
    }

    #[test]
    fn kernel_symmetry_and_psd_bound() {
        testkit::check("kernel-sym", 10, |rng, _| {
            let k = Kernel::Gaussian { h: 0.5 + rng.f64() };
            let a: Vec<f64> = (0..6).map(|_| rng.gauss()).collect();
            let b: Vec<f64> = (0..6).map(|_| rng.gauss()).collect();
            let kab = k.eval(&a, &b);
            let kba = k.eval(&b, &a);
            testkit::assert_close(kab, kba, 1e-14);
            assert!(kab > 0.0 && kab <= 1.0);
        });
    }

    #[test]
    fn poly_and_linear() {
        let lin = Kernel::Linear;
        assert_eq!(lin.eval(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let poly = Kernel::Polynomial { degree: 2, c: 1.0 };
        assert_eq!(poly.eval(&[1.0, 0.0], &[2.0, 0.0]), 9.0);
    }

    #[test]
    fn eval_from_parts_matches_eval() {
        let mut rng = Rng::new(4);
        for k in [Kernel::Gaussian { h: 0.7 }, Kernel::Polynomial { degree: 3, c: 0.5 }, Kernel::Linear] {
            let a: Vec<f64> = (0..8).map(|_| rng.gauss()).collect();
            let b: Vec<f64> = (0..8).map(|_| rng.gauss()).collect();
            let na2 = crate::linalg::dot(&a, &a);
            let nb2 = crate::linalg::dot(&b, &b);
            let ab = crate::linalg::dot(&a, &b);
            testkit::assert_close(k.eval(&a, &b), k.eval_from_parts(na2, nb2, ab), 1e-12);
        }
    }

    #[test]
    fn gram_psd_on_small_sample() {
        let mut rng = Rng::new(5);
        let x = Points::Dense(Mat::gauss(20, 3, &mut rng));
        let k = Kernel::Gaussian { h: 1.0 };
        let g = k.gram(&x);
        let eigs = crate::linalg::eig::sym_eig(&g).values;
        assert!(eigs.iter().all(|&e| e > -1e-10), "gram not PSD: {eigs:?}");
    }
}
