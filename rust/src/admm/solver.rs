//! The ADMM iteration (Algorithm 2 / lines 7–14 of Algorithm 3).

use crate::linalg::chol::Chol;
use crate::linalg::Mat;
use crate::obs;
use crate::util::threadpool;

/// Minimum `n * k_active` elements before the per-column grid updates go
/// parallel: per-column updates are O(n) flops, and below ~32k total
/// elements the two scoped-pool spawns per iteration cost more than they
/// save (bitwise identical either way — per-column arithmetic does not
/// depend on the schedule). Under Miri the threshold drops to 0 so the
/// tiny `miri_*` suites cross the real multi-thread column scatter.
const GRID_PAR_MIN_ELEMS: usize = if cfg!(miri) { 0 } else { 32_768 };

/// Anything that can solve (K + βI) x = b. Implemented by the HSS ULV
/// factorization (the paper's path) and by dense Cholesky (the exact
/// reference used in tests and the dense-ADMM baseline).
pub trait ShiftedSolve {
    fn solve_shifted(&self, b: &[f64]) -> Vec<f64>;

    /// Solve (K + βI) X = B for an n×k block of right-hand sides in one
    /// pass. Backends override this with blocked BLAS-3 kernels; the
    /// default solves column-by-column, which is always column-invariant
    /// (column j of the result is exactly `solve_shifted(B.col(j))`).
    /// Overrides must preserve that invariance bit-for-bit — the batched
    /// C-grid ([`AdmmSolver::run_grid`]) is validated against it.
    fn solve_shifted_multi(&self, b: &Mat) -> Mat {
        let mut out = Mat::zeros(b.rows(), b.cols());
        for j in 0..b.cols() {
            let col = self.solve_shifted(&b.col(j));
            for (i, v) in col.iter().enumerate() {
                out[(i, j)] = *v;
            }
        }
        out
    }

    fn dim(&self) -> usize;
}

impl ShiftedSolve for crate::hss::ulv::UlvFactor {
    fn solve_shifted(&self, b: &[f64]) -> Vec<f64> {
        if obs::enabled() {
            obs::emit(&obs::TraceEvent::UlvSolve { n: b.len(), rhs: 1 });
        }
        self.solve(b)
    }

    fn solve_shifted_multi(&self, b: &Mat) -> Mat {
        if obs::enabled() {
            obs::emit(&obs::TraceEvent::UlvSolve { n: b.rows(), rhs: b.cols() });
        }
        self.solve_mat(b)
    }

    fn dim(&self) -> usize {
        self.dim()
    }
}

/// Dense Cholesky of K + βI (callers build it with the shift applied).
pub struct DenseShifted {
    chol: Chol,
    n: usize,
}

impl DenseShifted {
    /// Build from an unshifted dense kernel matrix.
    pub fn new(k: &Mat, beta: f64) -> anyhow::Result<Self> {
        let mut kb = k.clone();
        kb.shift_diag(beta);
        Ok(DenseShifted { chol: Chol::new(&kb)?, n: k.rows() })
    }
}

impl ShiftedSolve for DenseShifted {
    fn solve_shifted(&self, b: &[f64]) -> Vec<f64> {
        self.chol.solve(b)
    }

    fn solve_shifted_multi(&self, b: &Mat) -> Mat {
        self.chol.solve_mat(b)
    }

    fn dim(&self) -> usize {
        self.n
    }
}

/// ADMM hyper-parameters.
#[derive(Clone, Copy, Debug)]
pub struct AdmmParams {
    /// Augmented-Lagrangian penalty β (paper: 1e2/1e3/1e4 staged by d).
    pub beta: f64,
    /// Fixed iteration count (paper: MaxIt = 10).
    pub max_it: usize,
    /// Over-relaxation factor α ∈ [1, 1.8] (Boyd §3.4.3; 1.0 = vanilla,
    /// the paper's setting). x is blended as αx + (1−α)z before the z
    /// and μ updates — an often-free convergence accelerator.
    pub relax: f64,
    /// Stop early once max(primal, dual) residual < tol (0 disables —
    /// the paper runs a fixed MaxIt instead).
    pub tol: f64,
}

impl Default for AdmmParams {
    fn default() -> Self {
        AdmmParams { beta: 1e2, max_it: 10, relax: 1.0, tol: 0.0 }
    }
}

impl AdmmParams {
    /// The paper's configuration for a given β.
    pub fn paper(beta: f64) -> Self {
        AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 }
    }
}

/// Result of an ADMM run.
#[derive(Clone, Debug)]
pub struct AdmmOutput {
    /// z^{MaxIt} — the box-feasible dual variables (the paper uses z, not
    /// x, as the trained coefficients: Algorithm 3 line 15).
    pub z: Vec<f64>,
    /// x^{MaxIt} (satisfies yᵀx = 0 exactly).
    pub x: Vec<f64>,
    /// Final multipliers.
    pub mu: Vec<f64>,
    /// Primal residual ‖x−z‖ per iteration.
    pub primal: Vec<f64>,
    /// Dual residual β‖z−z_prev‖ per iteration.
    pub dual: Vec<f64>,
    /// Dual objective  ½ zᵀYKYz − eᵀz  evaluated through the solver's K̃
    /// (only filled when requested).
    pub objective: Option<f64>,
}

/// Compact convergence summary of one trained C column: the iteration
/// count and final residuals the solver always computes (and, before
/// DESIGN.md §14, always dropped). Surfaced in `grid` summaries and
/// `report.json`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AdmmHistory {
    /// Iterations actually run (early-stop aware: ≤ `max_it`).
    pub iterations: usize,
    /// Last primal residual ‖x−z‖ (0 when no iteration ran).
    pub final_primal: f64,
    /// Last dual residual β‖z−z_prev‖ (0 when no iteration ran).
    pub final_dual: f64,
}

impl AdmmOutput {
    /// ADMM iterations actually run (== `primal.len()`).
    pub fn iterations(&self) -> usize {
        self.primal.len()
    }

    /// Final `(primal, dual)` residuals; zeros when no iteration ran.
    pub fn final_residuals(&self) -> (f64, f64) {
        (
            self.primal.last().copied().unwrap_or(0.0),
            self.dual.last().copied().unwrap_or(0.0),
        )
    }

    /// The per-column summary (`grid` output, `report.json`).
    pub fn history(&self) -> AdmmHistory {
        let (final_primal, final_dual) = self.final_residuals();
        AdmmHistory { iterations: self.iterations(), final_primal, final_dual }
    }
}

/// One ADMM half-iteration after the x-update: project z into [0, C],
/// update μ, and return the (primal, dual) residual norms. Shared by the
/// scalar and batched paths — and by the sharded consensus trainer
/// (`admm::consensus`) — so their per-element arithmetic cannot
/// diverge: the bit-for-bit `run` == `run_grid` == `K=1 consensus`
/// contracts depend on all three calling exactly this code.
pub(crate) fn admm_zmu_step(
    x: &[f64],
    z: &mut [f64],
    mu: &mut [f64],
    c: f64,
    beta: f64,
    relax: f64,
) -> (f64, f64) {
    // over-relaxation: x̂ = αx + (1−α)z (α = 1 → paper's scheme)
    // z = Π_[0,C](x̂ − μ/β), track dual residual
    let n = z.len();
    let mut dz2 = 0.0;
    for i in 0..n {
        let xh = relax * x[i] + (1.0 - relax) * z[i];
        let znew = (xh - mu[i] / beta).clamp(0.0, c);
        let d = znew - z[i];
        dz2 += d * d;
        z[i] = znew;
    }
    // μ = μ − β(x̂ − z), track primal residual (x̂ uses the new z)
    let mut pr2 = 0.0;
    for i in 0..n {
        let xh = relax * x[i] + (1.0 - relax) * z[i];
        let r = xh - z[i];
        pr2 += r * r;
        mu[i] -= beta * r;
    }
    (pr2.sqrt(), beta * dz2.sqrt())
}

/// Precomputed per-(h, β) state shared across all C values.
pub struct AdmmSolver<'a, S: ShiftedSolve> {
    solver: &'a S,
    /// Labels in the same ordering as the solver (tree order for HSS).
    y: &'a [f64],
    params: AdmmParams,
    /// Worker threads for the batched grid's per-column updates (the
    /// blocked solve parallelizes inside the backend itself).
    threads: usize,
    /// w = Y K_β⁻¹ e.
    w: Vec<f64>,
    /// w₁ = eᵀ K_β⁻¹ e.
    w1: f64,
}

impl<'a, S: ShiftedSolve> AdmmSolver<'a, S> {
    /// Precompute w and w₁ (lines 4–6 of Algorithm 3).
    pub fn new(solver: &'a S, y: &'a [f64], params: AdmmParams) -> Self {
        let n = solver.dim();
        assert_eq!(y.len(), n, "labels/solver dimension mismatch");
        let e = vec![1.0; n];
        let mut w = solver.solve_shifted(&e);
        let w1: f64 = w.iter().sum();
        for (wi, yi) in w.iter_mut().zip(y.iter()) {
            *wi *= yi;
        }
        AdmmSolver { solver, y, params, threads: 1, w, w1 }
    }

    /// Set the worker-thread count for [`AdmmSolver::run_grid`]'s
    /// per-column q/x/z/μ updates. Columns are independent and each
    /// keeps its exact serial arithmetic, so outputs are bit-for-bit
    /// identical for every thread count.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    /// Run MaxIt closed-form iterations for penalty `c` (lines 8–14),
    /// starting from zero.
    pub fn run(&self, c: f64) -> AdmmOutput {
        self.run_warm(c, None)
    }

    /// Run with an optional warm start: any feasible `(z, μ)` pair of
    /// the right dimension. The natural sources are the iterates of a
    /// previous **C value** (the paper's reuse story extended to the
    /// iterates themselves) and, since the multilevel trainer
    /// ([`crate::svm::multilevel`]), a previous **refinement level** —
    /// the coarse solution scattered onto the finer training set with
    /// zeros at newly admitted points. `z` is re-projected into the new
    /// box `[0, C]` element-wise, so any real vector is accepted; a warm
    /// start at (or near) the fixed point converges in no more
    /// iterations than the cold start (pinned by
    /// `warm_start_from_converged_terminates_no_slower`).
    pub fn run_warm(&self, c: f64, warm: Option<(&[f64], &[f64])>) -> AdmmOutput {
        let n = self.solver.dim();
        let beta = self.params.beta;
        let relax = self.params.relax.clamp(1.0, 1.9);
        let mut x = vec![0.0; n];
        let (mut z, mut mu) = match warm {
            Some((z0, mu0)) => {
                assert_eq!(z0.len(), n);
                assert_eq!(mu0.len(), n);
                // project the previous z into the new box
                (z0.iter().map(|&v| v.clamp(0.0, c)).collect(), mu0.to_vec())
            }
            None => (vec![0.0; n], vec![0.0; n]),
        };
        let mut primal = Vec::with_capacity(self.params.max_it);
        let mut dual = Vec::with_capacity(self.params.max_it);
        let mut q = vec![0.0; n];
        let mut u = vec![0.0; n];

        for k in 0..self.params.max_it {
            // q = e + μ + βz ; u = Y q
            for i in 0..n {
                q[i] = 1.0 + mu[i] + beta * z[i];
                u[i] = self.y[i] * q[i];
            }
            // v = K_β⁻¹ u ;  x = Y v − (w·q / w₁) w
            let v = self.solver.solve_shifted(&u);
            let w2: f64 = self.w.iter().zip(q.iter()).map(|(a, b)| a * b).sum();
            let ratio = w2 / self.w1;
            for i in 0..n {
                x[i] = self.y[i] * v[i] - ratio * self.w[i];
            }
            let (pr, du) = admm_zmu_step(&x, &mut z, &mut mu, c, beta, relax);
            primal.push(pr);
            dual.push(du);
            if obs::enabled() {
                obs::emit(&obs::TraceEvent::AdmmIter { c, iter: k, primal: pr, dual: du });
            }
            if self.params.tol > 0.0 {
                let p = *primal.last().unwrap();
                let d = *dual.last().unwrap();
                if p.max(d) < self.params.tol {
                    break;
                }
            }
        }

        let out = AdmmOutput { z, x, mu, primal, dual, objective: None };
        if obs::enabled() {
            let (pr, du) = out.final_residuals();
            obs::emit(&obs::TraceEvent::AdmmDone {
                c,
                iters: out.iterations(),
                primal: pr,
                dual: du,
            });
        }
        out
    }

    /// Run the whole C-grid in lockstep: one blocked multi-RHS solve per
    /// iteration advances every value of C at once, each column keeping
    /// its own z/μ iterates and box projection [0, C_j]. Column j of the
    /// result is identical to `run(cs[j])` — bit-for-bit, because both
    /// in-tree backends' `solve_shifted_multi` are column-invariant (see
    /// the `run_grid_matches_sequential_*` property tests).
    ///
    /// This turns the grid search's k·MaxIt sequential O(d·m) solves
    /// into MaxIt blocked O(d·m·k) GEMM-dominated sweeps — the missing
    /// half of the paper's "one factorization, every C" reuse story
    /// (Algorithm 3 / Tables 4–5).
    pub fn run_grid(&self, cs: &[f64]) -> Vec<AdmmOutput>
    where
        S: Sync,
    {
        self.run_grid_warm(cs, &[])
    }

    /// [`AdmmSolver::run_grid`] with per-column warm starts: `warms` is
    /// either empty (every column cold) or one `Option<(z0, μ0)>` per C
    /// value, initialized exactly as [`AdmmSolver::run_warm`] does
    /// (z clamped into that column's `[0, C_j]`, μ copied). Column j of
    /// the result is bit-for-bit `run_warm(cs[j], warms[j])` — the grid
    /// contract is unchanged because only the iterate *initialization*
    /// differs, never the per-iteration arithmetic. This is the
    /// multilevel trainer's batched refinement step: one blocked solve
    /// per iteration advances the whole C row from the previous level's
    /// scattered solution.
    pub fn run_grid_warm(
        &self,
        cs: &[f64],
        warms: &[Option<(&[f64], &[f64])>],
    ) -> Vec<AdmmOutput>
    where
        S: Sync,
    {
        let k = cs.len();
        if k == 0 {
            return Vec::new();
        }
        assert!(
            warms.is_empty() || warms.len() == k,
            "warm-start list must be empty or match the C grid ({} vs {k})",
            warms.len()
        );
        let n = self.solver.dim();
        let beta = self.params.beta;
        let relax = self.params.relax.clamp(1.0, 1.9);
        let mut xs = vec![vec![0.0; n]; k];
        let mut zs = vec![vec![0.0; n]; k];
        let mut mus = vec![vec![0.0; n]; k];
        for (j, warm) in warms.iter().enumerate() {
            if let Some((z0, mu0)) = warm {
                assert_eq!(z0.len(), n, "warm z dimension mismatch (column {j})");
                assert_eq!(mu0.len(), n, "warm mu dimension mismatch (column {j})");
                for i in 0..n {
                    zs[j][i] = z0[i].clamp(0.0, cs[j]);
                }
                mus[j].copy_from_slice(mu0);
            }
        }
        let mut primals: Vec<Vec<f64>> = vec![Vec::with_capacity(self.params.max_it); k];
        let mut duals: Vec<Vec<f64>> = vec![Vec::with_capacity(self.params.max_it); k];
        // with tol > 0 columns converge independently; frozen columns
        // keep their state and drop out of the updates AND the solve
        // (the RHS block is compacted to the active columns — safe
        // because the multi-solve is column-invariant)
        let mut active = vec![true; k];
        let mut w2s = vec![0.0; k];

        for it in 0..self.params.max_it {
            let act: Vec<usize> = (0..k).filter(|&j| active[j]).collect();
            if act.is_empty() {
                break;
            }
            // q_j = e + μ_j + βz_j ;  U[:, col] = Y q_j. The scalar
            // w·q_j is accumulated on the fly (same i-order fold as the
            // scalar path's sum, so bitwise identical) instead of
            // keeping k n-length q buffers alive. Columns are mutually
            // independent → parallel over the active set, each column
            // writing its own strided entries of U and its own w2 slot.
            let kact = act.len();
            let upd_threads = if n * kact >= GRID_PAR_MIN_ELEMS { self.threads } else { 1 };
            let mut u = Mat::zeros(n, kact);
            {
                let uc = threadpool::disjoint(u.data_mut());
                let w2c = threadpool::disjoint(&mut w2s);
                threadpool::parallel_for(upd_threads, kact, 1, |col| {
                    let j = act[col];
                    let (z, mu) = (&zs[j], &mus[j]);
                    let mut w2 = 0.0;
                    for i in 0..n {
                        let qi = 1.0 + mu[i] + beta * z[i];
                        // SAFETY: column `col` is owned by this task.
                        unsafe { *uc.get(i * kact + col) = self.y[i] * qi };
                        w2 += self.w[i] * qi;
                    }
                    // SAFETY: w2 slot j is owned by this task (each
                    // active j appears once in `act`).
                    unsafe { *w2c.get(j) = w2 };
                });
            }
            // V = K_β⁻¹ U — the single batched solve of the iteration
            let v = self.solver.solve_shifted_multi(&u);
            {
                let xc = threadpool::disjoint(&mut xs);
                let zc = threadpool::disjoint(&mut zs);
                let mc = threadpool::disjoint(&mut mus);
                let pc = threadpool::disjoint(&mut primals);
                let dc = threadpool::disjoint(&mut duals);
                let ac = threadpool::disjoint(&mut active);
                threadpool::parallel_for(upd_threads, kact, 1, |col| {
                    let j = act[col];
                    let c = cs[j];
                    // SAFETY: all slots indexed by j are owned by this
                    // task (each active j appears once in `act`).
                    unsafe {
                        let x = &mut *xc.get(j);
                        let z = &mut *zc.get(j);
                        let mu = &mut *mc.get(j);
                        // x_j = Y v_j − (w·q_j / w₁) w
                        let ratio = w2s[j] / self.w1;
                        for i in 0..n {
                            x[i] = self.y[i] * v[(i, col)] - ratio * self.w[i];
                        }
                        let (pr, du) = admm_zmu_step(x, z, mu, c, beta, relax);
                        (*pc.get(j)).push(pr);
                        (*dc.get(j)).push(du);
                        if self.params.tol > 0.0 && pr.max(du) < self.params.tol {
                            *ac.get(j) = false;
                        }
                    }
                });
            }
            // Passivity contract (DESIGN.md §14): trace events are read
            // out AFTER the parallel join, from values already written —
            // never from inside the update closures.
            if obs::enabled() {
                for &j in &act {
                    let pr = *primals[j].last().unwrap();
                    let du = *duals[j].last().unwrap();
                    obs::emit(&obs::TraceEvent::AdmmIter {
                        c: cs[j],
                        iter: it,
                        primal: pr,
                        dual: du,
                    });
                    if !active[j] {
                        obs::emit(&obs::TraceEvent::AdmmFreeze { c: cs[j], iter: it });
                    }
                }
            }
        }

        if obs::enabled() {
            for j in 0..k {
                obs::emit(&obs::TraceEvent::AdmmDone {
                    c: cs[j],
                    iters: primals[j].len(),
                    primal: primals[j].last().copied().unwrap_or(0.0),
                    dual: duals[j].last().copied().unwrap_or(0.0),
                });
            }
        }

        (0..k)
            .map(|j| AdmmOutput {
                z: std::mem::take(&mut zs[j]),
                x: std::mem::take(&mut xs[j]),
                mu: std::mem::take(&mut mus[j]),
                primal: std::mem::take(&mut primals[j]),
                dual: std::mem::take(&mut duals[j]),
                objective: None,
            })
            .collect()
    }

    /// w₁ = eᵀK_β⁻¹e (positive for SPD K_β — useful sanity probe).
    pub fn w1(&self) -> f64 {
        self.w1
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::kernel::Kernel;
    use crate::util::prng::Rng;

    /// Tiny dense SVM setup: returns (K, y).
    fn tiny_problem(n: usize, rng: &mut Rng) -> (Mat, Vec<f64>) {
        let ds = synth::two_moons(n, 0.08, rng);
        let kernel = Kernel::Gaussian { h: 0.5 };
        (kernel.gram(&ds.x), ds.y)
    }

    #[test]
    fn x_iterates_satisfy_equality_constraint() {
        let mut rng = Rng::new(51);
        let (k, y) = tiny_problem(80, &mut rng);
        let solver = DenseShifted::new(&k, 10.0).unwrap();
        let admm = AdmmSolver::new(&solver, &y, AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 });
        let out = admm.run(1.0);
        let ytx: f64 = y.iter().zip(out.x.iter()).map(|(a, b)| a * b).sum();
        assert!(ytx.abs() < 1e-8, "yᵀx = {ytx}");
    }

    #[test]
    fn z_is_box_feasible() {
        let mut rng = Rng::new(52);
        let (k, y) = tiny_problem(60, &mut rng);
        let solver = DenseShifted::new(&k, 5.0).unwrap();
        let admm = AdmmSolver::new(&solver, &y, AdmmParams { beta: 5.0, max_it: 10, relax: 1.0, tol: 0.0 });
        let c = 2.5;
        let out = admm.run(c);
        assert!(out.z.iter().all(|&v| (0.0..=c).contains(&v)));
    }

    #[test]
    fn residuals_decrease_with_iterations() {
        let mut rng = Rng::new(53);
        let (k, y) = tiny_problem(100, &mut rng);
        let solver = DenseShifted::new(&k, 10.0).unwrap();
        let admm = AdmmSolver::new(&solver, &y, AdmmParams { beta: 10.0, max_it: 60, relax: 1.0, tol: 0.0 });
        let out = admm.run(1.0);
        // the first iterations can sit inside the box (residual ~0), so
        // compare the peak against the tail instead of head vs tail
        let peak = out.primal.iter().cloned().fold(0.0f64, f64::max);
        let tail = *out.primal.last().unwrap();
        assert!(peak > 0.0, "ADMM never moved");
        assert!(tail < peak * 0.2, "primal residual not decreasing: peak {peak} → tail {tail}");
        assert!(tail < 0.05, "final primal residual too large: {tail}");
    }

    #[test]
    fn admm_approaches_exact_qp_solution() {
        // Long ADMM run must agree with the KKT conditions of problem (1):
        // for the converged z: if 0 < z_i < C then y_i f(x_i) ≈ 1 where
        // f = Σ_j z_j y_j K(·, x_j) + b (margin support vectors).
        let mut rng = Rng::new(54);
        let n = 80;
        let ds = synth::two_moons(n, 0.05, &mut rng);
        let kernel = Kernel::Gaussian { h: 0.5 };
        let k = kernel.gram(&ds.x);
        let y = ds.y.clone();
        let beta = 1.0;
        let c = 10.0;
        let solver = DenseShifted::new(&k, beta).unwrap();
        let admm = AdmmSolver::new(&solver, &y, AdmmParams { beta, max_it: 4000, relax: 1.0, tol: 0.0 });
        let out = admm.run(c);
        // bias from margin SVs
        let mut b_acc = 0.0;
        let mut b_cnt = 0usize;
        for j in 0..n {
            if out.z[j] > 1e-3 * c && out.z[j] < c * (1.0 - 1e-3) {
                let mut f = 0.0;
                for i in 0..n {
                    f += y[i] * out.z[i] * k[(i, j)];
                }
                b_acc += y[j] - f;
                b_cnt += 1;
            }
        }
        assert!(b_cnt > 0, "no margin support vectors found");
        let b = b_acc / b_cnt as f64;
        // every margin SV must sit on the margin: y_j (f_j + b) ≈ 1
        for j in 0..n {
            if out.z[j] > 1e-2 * c && out.z[j] < c * (1.0 - 1e-2) {
                let mut f = b;
                for i in 0..n {
                    f += y[i] * out.z[i] * k[(i, j)];
                }
                let margin = y[j] * f;
                assert!(
                    (margin - 1.0).abs() < 0.05,
                    "margin SV {j} violates KKT: y·f = {margin}"
                );
            }
        }
    }

    fn assert_outputs_bitwise(grid: &AdmmOutput, single: &AdmmOutput, label: &str) {
        assert_eq!(grid.z, single.z, "{label}: z mismatch");
        assert_eq!(grid.x, single.x, "{label}: x mismatch");
        assert_eq!(grid.mu, single.mu, "{label}: mu mismatch");
        assert_eq!(grid.primal, single.primal, "{label}: primal residuals mismatch");
        assert_eq!(grid.dual, single.dual, "{label}: dual residuals mismatch");
    }

    #[test]
    fn run_grid_matches_sequential_dense_bitwise() {
        let mut rng = Rng::new(56);
        let (k, y) = tiny_problem(90, &mut rng);
        let solver = DenseShifted::new(&k, 10.0).unwrap();
        let admm = AdmmSolver::new(
            &solver,
            &y,
            AdmmParams { beta: 10.0, max_it: 12, relax: 1.0, tol: 0.0 },
        );
        let cs = [0.05, 0.3, 1.0, 2.5, 10.0];
        let grid = admm.run_grid(&cs);
        assert_eq!(grid.len(), cs.len());
        for (j, &c) in cs.iter().enumerate() {
            let single = admm.run(c);
            assert_outputs_bitwise(&grid[j], &single, &format!("dense C={c}"));
        }
    }

    #[test]
    fn run_grid_matches_sequential_ulv_bitwise() {
        use crate::hss::compress::compress;
        use crate::hss::ulv::UlvFactor;
        use crate::hss::HssParams;
        let mut rng = Rng::new(57);
        let ds = synth::blobs(260, 3, 4, 0.3, &mut rng);
        let kernel = Kernel::Gaussian { h: 1.0 };
        let comp = compress(&ds, &kernel, &HssParams::near_exact(), 1);
        let beta = 5.0;
        let ulv = UlvFactor::new(&comp.hss, beta).unwrap();
        let admm = AdmmSolver::new(
            &ulv,
            &comp.pds.y,
            AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 },
        );
        let cs = [0.1, 1.0, 3.0, 10.0];
        let grid = admm.run_grid(&cs);
        for (j, &c) in cs.iter().enumerate() {
            let single = admm.run(c);
            assert_outputs_bitwise(&grid[j], &single, &format!("ulv C={c}"));
        }
    }

    #[test]
    fn run_grid_matches_sequential_with_relaxation() {
        // over-relaxed runs go through the same arithmetic, but the
        // contract only promises 1e-10 agreement away from relax = 1
        let mut rng = Rng::new(58);
        let (k, y) = tiny_problem(70, &mut rng);
        let solver = DenseShifted::new(&k, 5.0).unwrap();
        let admm = AdmmSolver::new(
            &solver,
            &y,
            AdmmParams { beta: 5.0, max_it: 15, relax: 1.5, tol: 0.0 },
        );
        let cs = [0.2, 1.0, 4.0];
        let grid = admm.run_grid(&cs);
        for (j, &c) in cs.iter().enumerate() {
            let single = admm.run(c);
            crate::util::testkit::assert_allclose(&grid[j].z, &single.z, 1e-10);
            crate::util::testkit::assert_allclose(&grid[j].mu, &single.mu, 1e-10);
        }
    }

    #[test]
    fn run_grid_early_stops_per_column() {
        // with tol > 0 each column must stop at the same iteration count
        // (and with the same iterates) as its sequential run
        let mut rng = Rng::new(59);
        let (k, y) = tiny_problem(60, &mut rng);
        let solver = DenseShifted::new(&k, 10.0).unwrap();
        let admm = AdmmSolver::new(
            &solver,
            &y,
            AdmmParams { beta: 10.0, max_it: 200, relax: 1.0, tol: 1e-4 },
        );
        let cs = [0.1, 1.0, 10.0];
        let grid = admm.run_grid(&cs);
        for (j, &c) in cs.iter().enumerate() {
            let single = admm.run(c);
            assert_eq!(
                grid[j].primal.len(),
                single.primal.len(),
                "C={c}: different stopping iteration"
            );
            assert_outputs_bitwise(&grid[j], &single, &format!("tol C={c}"));
        }
    }

    #[test]
    fn run_grid_empty_and_single() {
        let mut rng = Rng::new(60);
        let (k, y) = tiny_problem(40, &mut rng);
        let solver = DenseShifted::new(&k, 5.0).unwrap();
        let admm = AdmmSolver::new(&solver, &y, AdmmParams::default());
        assert!(admm.run_grid(&[]).is_empty());
        let one = admm.run_grid(&[1.5]);
        assert_eq!(one.len(), 1);
        assert_outputs_bitwise(&one[0], &admm.run(1.5), "singleton grid");
    }

    #[test]
    fn miri_run_grid_parallel_columns_match_scalar() {
        // Tiny instance for the Miri lane: GRID_PAR_MIN_ELEMS drops to 0
        // under Miri, so with_threads(2) sends the per-column q/x/z/μ
        // scatter through real worker threads — and each column must
        // still be bit-for-bit the scalar run's.
        let mut rng = Rng::new(61);
        let (k, y) = tiny_problem(10, &mut rng);
        let solver = DenseShifted::new(&k, 1.5).unwrap();
        let admm = AdmmSolver::new(
            &solver,
            &y,
            AdmmParams { beta: 1.5, max_it: 3, relax: 1.0, tol: 0.0 },
        )
        .with_threads(2);
        let cs = [0.5, 1.0, 2.0];
        let grid = admm.run_grid(&cs);
        for (j, &c) in cs.iter().enumerate() {
            let single = admm.run(c);
            assert_outputs_bitwise(&grid[j], &single, &format!("miri C={c}"));
        }
    }

    #[test]
    fn warm_start_from_converged_terminates_no_slower() {
        // the run_warm contract: restarting from the converged (z, μ)
        // pair (any feasible warm pair — previous C value or previous
        // level) must terminate in ≤ the cold iteration count
        let mut rng = Rng::new(62);
        let (k, y) = tiny_problem(70, &mut rng);
        let solver = DenseShifted::new(&k, 10.0).unwrap();
        let admm = AdmmSolver::new(
            &solver,
            &y,
            AdmmParams { beta: 10.0, max_it: 500, relax: 1.0, tol: 1e-5 },
        );
        let c = 1.0;
        let cold = admm.run(c);
        assert!(cold.iterations() > 1, "cold run converged too fast to test warm starts");
        let warm = admm.run_warm(c, Some((&cold.z, &cold.mu)));
        assert!(
            warm.iterations() <= cold.iterations(),
            "warm start from the converged solution took {} iterations vs {} cold",
            warm.iterations(),
            cold.iterations()
        );
    }

    #[test]
    fn run_grid_warm_matches_sequential_run_warm_bitwise() {
        // per-column warm starts through the batched path must equal
        // the scalar run_warm column-by-column, including a mixed
        // warm/cold grid (None columns stay bit-for-bit run(c))
        let mut rng = Rng::new(63);
        let (k, y) = tiny_problem(80, &mut rng);
        let solver = DenseShifted::new(&k, 5.0).unwrap();
        let admm = AdmmSolver::new(
            &solver,
            &y,
            AdmmParams { beta: 5.0, max_it: 8, relax: 1.0, tol: 0.0 },
        );
        let cs = [0.2, 1.0, 4.0];
        // a feasible warm pair from a short pre-run at a different C
        let pre = admm.run(0.7);
        let warms: Vec<Option<(&[f64], &[f64])>> = vec![
            Some((pre.z.as_slice(), pre.mu.as_slice())),
            None,
            Some((pre.z.as_slice(), pre.mu.as_slice())),
        ];
        let grid = admm.run_grid_warm(&cs, &warms);
        for (j, &c) in cs.iter().enumerate() {
            let single = admm.run_warm(c, warms[j]);
            assert_outputs_bitwise(&grid[j], &single, &format!("warm grid C={c}"));
        }
    }

    #[test]
    fn w1_positive_for_spd() {
        let mut rng = Rng::new(55);
        let (k, y) = tiny_problem(40, &mut rng);
        let solver = DenseShifted::new(&k, 1.0).unwrap();
        let admm = AdmmSolver::new(&solver, &y, AdmmParams::default());
        assert!(admm.w1() > 0.0);
    }
}
