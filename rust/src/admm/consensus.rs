//! Sample-partitioned consensus ADMM over on-disk shards — the
//! out-of-core training path (Boyd et al. 2011 §8.2.3 adapted to the
//! kernel dual).
//!
//! # Formulation
//!
//! The in-memory path approximates the full kernel matrix K by one HSS
//! matrix. Out of core we take the partition one structural level
//! higher: rows are split round-robin into K shards
//! ([`crate::data::shard`]), and the kernel is approximated
//! **block-diagonally** — K̃ = diag(K̃₁, …, K̃_K) with one HSS
//! compression per shard and the shard-level off-diagonal blocks
//! dropped (exactly as HSS itself compresses — rather than drops — its
//! own off-diagonal blocks; K = 1 degenerates to the in-memory
//! algorithm, bit-for-bit). Under that approximation the dual
//!
//! ```text
//!   min ½ xᵀY K̃ Y x − eᵀx   s.t.  yᵀx = 0,  0 ≤ x ≤ C
//! ```
//!
//! separates per shard except for the single scalar coupling yᵀx = 0.
//! Each ADMM iteration therefore runs the closed-form x/z/μ updates of
//! [`super::solver`] independently inside every shard, with the global
//! equality multiplier — the scalar `ratio = (Σ_j w₂ⱼ) / (Σ_j w₁ⱼ)` —
//! reduced across shards in **fixed shard-major order** each iteration
//! (the "averaged consensus step": it is what makes the per-shard x
//! iterates agree on yᵀx = 0 globally). Per-shard duals μⱼ persist
//! across iterations (warm-started, never reset), and
//! [`crate::admm::solver::admm_zmu_step`] is shared verbatim with the
//! in-memory path so the per-element arithmetic cannot diverge.
//!
//! # Determinism
//!
//! The trained model is a pure function of (shard count, shard
//! content) — independent of the thread count:
//!
//! * shard-major deterministic RNG forks: shard 0 compresses with the
//!   base [`HssParams::seed`] (so K = 1 IS the in-memory trainer),
//!   shard s > 0 with the s-th fork of a base stream, drawn in
//!   ascending shard order;
//! * every cross-shard reduction (w₁, w₂, residual norms, bias terms,
//!   SV concatenation) folds in ascending shard order, starting from
//!   the first part (not 0.0, which could flip a −0.0 sign bit on the
//!   K = 1 path);
//! * within a shard, compression/ULV/matvec inherit PR 2's bitwise
//!   thread-invariance contract.
//!
//! # Memory model
//!
//! Raw shard points are resident **one shard at a time**: the build
//! phase loads shard s, compresses it, keeps only the O(nⱼ·r) HSS +
//! ULV state (plus O(nⱼ) labels/vectors) and drops the points before
//! loading shard s+1. The ADMM phase touches no raw data at all; model
//! assembly re-reads each shard's points from disk (bit-exact hex
//! round-trip) one at a time to extract support vectors. Peak RSS is
//! therefore O(max_j nnzⱼ + Σⱼ nⱼ·r), never O(n·d) dense — the
//! contract the `oos-smoke` CI lane enforces with a VmHWM bound.

use crate::admm::solver::{admm_zmu_step, AdmmParams, DenseShifted, ShiftedSolve};
use crate::data::libsvm::Repr;
use crate::data::shard::ShardSet;
use crate::data::{Dataset, Points};
use crate::hss::compress::{compress, Compressed};
use crate::hss::matvec;
use crate::hss::ulv::UlvFactor;
use crate::hss::{Hss, HssParams};
use crate::kernel::Kernel;
use crate::linalg::Mat;
use crate::obs;
use crate::svm::model::SvmModel;
use crate::util::prng::Rng;
use crate::util::timer::{PhaseTimer, Timer};
use anyhow::{bail, Result};
use std::time::Duration;

/// Shard-major reduction: ascending shard order, fold seeded with the
/// first part so a single-shard reduction returns its part verbatim
/// (`0.0 + x` is not the identity for `x = −0.0`; bitwise K = 1
/// equality with the in-memory trainer requires the verbatim value).
fn fold_sum(parts: &[f64]) -> f64 {
    let mut acc = parts[0];
    for p in &parts[1..] {
        acc += p;
    }
    acc
}

/// Per-shard solve/matvec backend. Shards with ≥ 2 rows go through the
/// standard HSS pipeline; a single-row shard (K close to n) falls back
/// to the exact 1×1 dense kernel — the HSS cluster tree needs n ≥ 2.
enum ShardBackend {
    Hss { hss: Hss, ulv: UlvFactor },
    Dense { gram: Mat, chol: DenseShifted },
}

impl ShardBackend {
    fn solve(&self, b: &[f64]) -> Vec<f64> {
        match self {
            ShardBackend::Hss { ulv, .. } => ulv.solve(b),
            ShardBackend::Dense { chol, .. } => chol.solve_shifted(b),
        }
    }

    fn solve_multi(&self, b: &Mat) -> Mat {
        match self {
            ShardBackend::Hss { ulv, .. } => ulv.solve_mat(b),
            ShardBackend::Dense { chol, .. } => chol.solve_shifted_multi(b),
        }
    }

    /// K̃ⱼ v (unshifted) — the bias assembly matvec.
    fn matvec(&self, v: &[f64], threads: usize) -> Vec<f64> {
        match self {
            ShardBackend::Hss { hss, .. } => matvec::matvec_threads(hss, v, threads),
            ShardBackend::Dense { gram, .. } => {
                let n = gram.rows();
                let mut out = vec![0.0; n];
                for (i, oi) in out.iter_mut().enumerate() {
                    let mut acc = 0.0;
                    for j in 0..n {
                        acc += gram[(i, j)] * v[j];
                    }
                    *oi = acc;
                }
                out
            }
        }
    }
}

/// One resident shard: compressed kernel + precomputed ADMM vectors.
/// The raw points are NOT here — they were dropped after compression.
struct ShardEngine {
    /// Original shard id (ascending across `engines`; empty shards of
    /// the set are skipped).
    shard: usize,
    backend: ShardBackend,
    /// Tree-order → shard-row permutation (identity for the dense
    /// fallback), used to re-extract SV rows from the reloaded shard.
    perm: Vec<usize>,
    /// Labels in tree order.
    y: Vec<f64>,
    /// wⱼ = Yⱼ K_{β,j}⁻¹ e.
    w: Vec<f64>,
    /// w₁ⱼ = eᵀ K_{β,j}⁻¹ e (shard partial of the global w₁).
    w1: f64,
    n: usize,
}

/// Build/run statistics (the sharded analog of
/// [`crate::svm::TrainStats`], with per-shard totals).
#[derive(Clone, Debug, Default)]
pub struct ConsensusStats {
    /// Shard count K (including empty shards).
    pub shards: usize,
    /// Shards that actually hold rows (= engine count).
    pub resident_shards: usize,
    /// Total training rows across shards.
    pub rows: usize,
    pub compress_secs: f64,
    pub factor_secs: f64,
    /// Total compressed memory across all shard engines, bytes.
    pub hss_memory_bytes: usize,
    /// Max HSS rank over all shards.
    pub hss_max_rank: usize,
    /// Total kernel evaluations across shard compressions.
    pub kernel_evals: usize,
}

/// Result of a consensus ADMM run for one C: per-shard iterates (tree
/// order within each shard, shards ascending) plus the global
/// per-iteration residual norms (root-sum-square over shards).
#[derive(Clone, Debug)]
pub struct ConsensusOutput {
    pub z: Vec<Vec<f64>>,
    pub x: Vec<Vec<f64>>,
    pub mu: Vec<Vec<f64>>,
    pub primal: Vec<f64>,
    pub dual: Vec<f64>,
}

/// The out-of-core trainer: one [`ShardEngine`] per non-empty shard,
/// built one shard at a time (see the module docs for the memory
/// model), then consensus ADMM over all of them with the C-grid in
/// lockstep per shard (the same multi-RHS machinery as
/// [`crate::admm::AdmmSolver::run_grid`]).
pub struct ConsensusTrainer {
    pub kernel: Kernel,
    admm: AdmmParams,
    threads: usize,
    repr: Repr,
    engines: Vec<ShardEngine>,
    /// Global w₁ = Σⱼ w₁ⱼ (shard-major fold).
    w1: f64,
    /// Original label encoding (manifest), stamped into models.
    labels: [f64; 2],
    /// Total rows.
    n: usize,
    /// Accumulating phase profile (compression/factorization seeded by
    /// `build`, admm/sv-extract recorded as the stages run). Purely
    /// observational — never read by the training arithmetic.
    phases: PhaseTimer,
}

/// Per-shard compression seed: shard 0 keeps the base seed (K = 1 must
/// BE the in-memory trainer), shard s > 0 draws the s-th value of a
/// deterministic fork stream in ascending shard order — so the seed of
/// a given shard depends only on (base seed, shard id), not on K or
/// the thread count.
fn shard_seed(base: u64, shard: usize) -> u64 {
    if shard == 0 {
        return base;
    }
    let mut rng = Rng::new(base);
    let mut seed = base;
    for s in 1..=shard {
        seed = rng.fork(s as u64).next_u64();
    }
    seed
}

fn build_engine(
    ds: &Dataset,
    shard: usize,
    kernel: Kernel,
    params: &HssParams,
    beta: f64,
    threads: usize,
    stats: &mut ConsensusStats,
) -> Result<ShardEngine> {
    let n = ds.len();
    let compress_secs;
    let factor_secs;
    let t = Timer::start();
    let (backend, perm, y) = if n >= 2 {
        let Compressed { hss, pds, stats: cs } = compress(ds, &kernel, params, threads);
        compress_secs = t.secs();
        stats.hss_max_rank = stats.hss_max_rank.max(cs.max_rank);
        stats.kernel_evals += cs.kernel_evals;
        let t = Timer::start();
        let ulv = UlvFactor::new_threaded(&hss, beta, threads)?;
        factor_secs = t.secs();
        stats.hss_memory_bytes += hss.memory_bytes() + ulv.memory_bytes();
        let perm = hss.perm.clone();
        let y = pds.y.clone();
        // pds (the shard's points) drops here — only the compressed
        // representation stays resident
        (ShardBackend::Hss { hss, ulv }, perm, y)
    } else {
        let gram = kernel.gram(&ds.x);
        compress_secs = t.secs();
        let t = Timer::start();
        let chol = DenseShifted::new(&gram, beta)?;
        factor_secs = t.secs();
        stats.hss_memory_bytes += 2 * n * n * std::mem::size_of::<f64>();
        (ShardBackend::Dense { gram, chol }, (0..n).collect(), ds.y.clone())
    };
    stats.compress_secs += compress_secs;
    stats.factor_secs += factor_secs;
    if obs::enabled() {
        obs::emit(&obs::TraceEvent::ShardBuild {
            shard,
            rows: n,
            compress_secs,
            factor_secs,
            rss_bytes: crate::util::bench::peak_rss_bytes().unwrap_or(0),
        });
    }

    // wⱼ = Yⱼ K_β⁻¹ e, w₁ⱼ = Σᵢ (K_β⁻¹ e)ᵢ — the exact arithmetic of
    // AdmmSolver::new, per shard
    let e = vec![1.0; n];
    let mut w = backend.solve(&e);
    let w1: f64 = w.iter().sum();
    for (wi, yi) in w.iter_mut().zip(y.iter()) {
        *wi *= yi;
    }
    Ok(ShardEngine { shard, backend, perm, y, w, w1, n })
}

impl ConsensusTrainer {
    /// Build one engine per non-empty shard, ascending, loading raw
    /// points one shard at a time. `repr` is resolved globally by the
    /// manifest (every shard shares one representation).
    pub fn build(
        shards: &ShardSet,
        repr: Repr,
        kernel: Kernel,
        params: &HssParams,
        admm: AdmmParams,
        threads: usize,
    ) -> Result<(ConsensusTrainer, ConsensusStats)> {
        let threads = threads.max(1);
        let m = shards.manifest();
        if m.rows == 0 {
            bail!("cannot train on an empty shard set");
        }
        let mut stats = ConsensusStats {
            shards: m.shards,
            rows: m.rows,
            ..ConsensusStats::default()
        };
        let mut engines = Vec::new();
        for s in 0..m.shards {
            if m.shard_rows[s] == 0 {
                continue;
            }
            let ds = shards.load_shard(s, repr)?;
            let sp = params.with_seed(shard_seed(params.seed, s));
            engines.push(build_engine(&ds, s, kernel, &sp, admm.beta, threads, &mut stats)?);
            // ds (raw points) drops before the next shard loads
        }
        stats.resident_shards = engines.len();
        let w1_parts: Vec<f64> = engines.iter().map(|e| e.w1).collect();
        let w1 = fold_sum(&w1_parts);
        let phases = PhaseTimer::new();
        phases.add("compression", Duration::from_secs_f64(stats.compress_secs));
        phases.add("factorization", Duration::from_secs_f64(stats.factor_secs));
        Ok((
            ConsensusTrainer {
                kernel,
                admm,
                threads,
                repr,
                engines,
                w1,
                labels: m.label_pair,
                n: m.rows,
                phases,
            },
            stats,
        ))
    }

    /// Total training rows.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Non-empty shard count.
    pub fn resident_shards(&self) -> usize {
        self.engines.len()
    }

    /// Global w₁ = eᵀ K̃_β⁻¹ e (positive for SPD shard blocks).
    pub fn w1(&self) -> f64 {
        self.w1
    }

    /// `(phase, secs, count)` rows in pipeline order: compression and
    /// factorization from the build, plus every admm / sv-extract stage
    /// run so far. Feeds `report.json`.
    pub fn phases(&self) -> Vec<(String, f64, u64)> {
        self.phases.report()
    }

    /// Run the consensus ADMM for every C in lockstep (cold start).
    pub fn train_grid(&self, cs: &[f64]) -> Vec<ConsensusOutput> {
        self.train_grid_warm(cs, None)
    }

    /// [`Self::train_grid`] with an optional warm start: every column
    /// seeds z (projected into its [0, C] box) and μ from a previous
    /// run's per-shard iterates — the cross-C extension of the
    /// warm-started per-shard duals that already persist across
    /// iterations within a run.
    pub fn train_grid_warm(
        &self,
        cs: &[f64],
        warm: Option<&ConsensusOutput>,
    ) -> Vec<ConsensusOutput> {
        let k = cs.len();
        if k == 0 {
            return Vec::new();
        }
        let ne = self.engines.len();
        let beta = self.admm.beta;
        let relax = self.admm.relax.clamp(1.0, 1.9);

        // state[engine][column]
        let mut xs: Vec<Vec<Vec<f64>>> =
            self.engines.iter().map(|e| vec![vec![0.0; e.n]; k]).collect();
        let mut zs: Vec<Vec<Vec<f64>>> = match warm {
            Some(w) => self
                .engines
                .iter()
                .enumerate()
                .map(|(ei, e)| {
                    assert_eq!(w.z[ei].len(), e.n, "warm start shard size mismatch");
                    cs.iter()
                        .map(|&c| w.z[ei].iter().map(|&v| v.clamp(0.0, c)).collect())
                        .collect()
                })
                .collect(),
            None => self.engines.iter().map(|e| vec![vec![0.0; e.n]; k]).collect(),
        };
        let mut mus: Vec<Vec<Vec<f64>>> = match warm {
            Some(w) => self
                .engines
                .iter()
                .enumerate()
                .map(|(ei, _)| vec![w.mu[ei].clone(); k])
                .collect(),
            None => self.engines.iter().map(|e| vec![vec![0.0; e.n]; k]).collect(),
        };
        let mut primals: Vec<Vec<f64>> = vec![Vec::with_capacity(self.admm.max_it); k];
        let mut duals: Vec<Vec<f64>> = vec![Vec::with_capacity(self.admm.max_it); k];
        let mut active = vec![true; k];
        let admm_timer = Timer::start();

        for it in 0..self.admm.max_it {
            let act: Vec<usize> = (0..k).filter(|&j| active[j]).collect();
            if act.is_empty() {
                break;
            }
            let kact = act.len();

            // Pass A — consensus reduction: per-column w₂ partials in
            // fixed shard-major order (qᵢ is recomputed cheaply in pass
            // B; the i-order fold per shard is exactly run_grid's)
            let mut ratios = vec![0.0; kact];
            {
                let mut w2_parts = vec![vec![0.0; ne]; kact];
                for (ei, eng) in self.engines.iter().enumerate() {
                    for (ci, &j) in act.iter().enumerate() {
                        let (z, mu) = (&zs[ei][j], &mus[ei][j]);
                        let mut w2 = 0.0;
                        for i in 0..eng.n {
                            let qi = 1.0 + mu[i] + beta * z[i];
                            w2 += eng.w[i] * qi;
                        }
                        w2_parts[ci][ei] = w2;
                    }
                }
                for (ci, parts) in w2_parts.iter().enumerate() {
                    ratios[ci] = fold_sum(parts) / self.w1;
                }
            }

            // Pass B — per shard: rebuild the active-column RHS block,
            // one blocked multi-RHS solve, then the shared x/z/μ
            // updates per column
            let mut pr2 = vec![vec![0.0; ne]; kact];
            let mut du2 = vec![vec![0.0; ne]; kact];
            for (ei, eng) in self.engines.iter().enumerate() {
                let mut u = Mat::zeros(eng.n, kact);
                for (ci, &j) in act.iter().enumerate() {
                    let (z, mu) = (&zs[ei][j], &mus[ei][j]);
                    for i in 0..eng.n {
                        let qi = 1.0 + mu[i] + beta * z[i];
                        u[(i, ci)] = eng.y[i] * qi;
                    }
                }
                let v = eng.backend.solve_multi(&u);
                for (ci, &j) in act.iter().enumerate() {
                    let x = &mut xs[ei][j];
                    let ratio = ratios[ci];
                    for i in 0..eng.n {
                        x[i] = eng.y[i] * v[(i, ci)] - ratio * eng.w[i];
                    }
                    let (pr, du) =
                        admm_zmu_step(x, &mut zs[ei][j], &mut mus[ei][j], cs[j], beta, relax);
                    pr2[ci][ei] = pr * pr;
                    du2[ci][ei] = du * du;
                }
            }

            // Global residuals: root-sum-square over shards, fixed
            // shard-major fold. (For K = 1 this is sqrt(pr²) — equal to
            // the in-memory residual up to the last ulp; the bitwise
            // K = 1 model contract therefore holds at tol = 0, the
            // default and the paper's setting, where residuals are
            // reporting-only.)
            for (ci, &j) in act.iter().enumerate() {
                let pr = fold_sum(&pr2[ci]).sqrt();
                let du = fold_sum(&du2[ci]).sqrt();
                primals[j].push(pr);
                duals[j].push(du);
                if self.admm.tol > 0.0 && pr.max(du) < self.admm.tol {
                    active[j] = false;
                }
                // Passivity: the consensus ratio and residuals are read
                // back out AFTER they fed the update — the trace never
                // participates in the arithmetic.
                if obs::enabled() {
                    obs::emit(&obs::TraceEvent::ConsensusIter {
                        iter: it,
                        c: cs[j],
                        ratio: ratios[ci],
                    });
                }
            }
        }
        self.phases.add("admm", admm_timer.elapsed());

        (0..k)
            .map(|j| ConsensusOutput {
                z: self.engines.iter().enumerate().map(|(ei, _)| std::mem::take(&mut zs[ei][j])).collect(),
                x: self.engines.iter().enumerate().map(|(ei, _)| std::mem::take(&mut xs[ei][j])).collect(),
                mu: self.engines.iter().enumerate().map(|(ei, _)| std::mem::take(&mut mus[ei][j])).collect(),
                primal: std::mem::take(&mut primals[j]),
                dual: std::mem::take(&mut duals[j]),
            })
            .collect()
    }

    /// One-C convenience: run + assemble.
    pub fn train_c(&self, shards: &ShardSet, c: f64) -> Result<(SvmModel, ConsensusOutput)> {
        let mut outs = self.train_grid(&[c]);
        let out = outs.pop().expect("one column");
        let model = self.assemble_model(shards, &out, c)?;
        Ok((model, out))
    }

    /// Assemble the model from per-shard z: the exact arithmetic of the
    /// in-memory `assemble_model`, with every global sum folded
    /// shard-major and the bias matvec going through each shard's K̃ⱼ
    /// (consistent with the block-diagonal training objective). Raw
    /// shard points are re-read from disk one shard at a time to
    /// extract SV rows (bit-exact hex round-trip); SVs concatenate
    /// shard-major in tree order. The persisted result is a plain
    /// [`SvmModel`] — predict/serve paths are unchanged.
    pub fn assemble_model(
        &self,
        shards: &ShardSet,
        out: &ConsensusOutput,
        c: f64,
    ) -> Result<SvmModel> {
        let sv_timer = Timer::start();
        let ne = self.engines.len();
        assert_eq!(out.z.len(), ne, "output/engine shard count mismatch");
        let sv_tol = 1e-8 * c.max(1.0);
        let margin_lo = 1e-6 * c;
        let margin_hi = c * (1.0 - 1e-6);

        let mut zys: Vec<Vec<f64>> = Vec::with_capacity(ne);
        let mut ebars: Vec<Vec<f64>> = Vec::with_capacity(ne);
        let mut m_parts = Vec::with_capacity(ne);
        for (ei, eng) in self.engines.iter().enumerate() {
            let z = &out.z[ei];
            let zy: Vec<f64> = z.iter().zip(eng.y.iter()).map(|(zi, yi)| zi * yi).collect();
            let ebar: Vec<f64> = z
                .iter()
                .map(|&zi| if zi > margin_lo && zi < margin_hi { 1.0 } else { 0.0 })
                .collect();
            m_parts.push(ebar.iter().sum::<f64>());
            zys.push(zy);
            ebars.push(ebar);
        }
        let m_count = fold_sum(&m_parts);

        // same 8k matvec-threads threshold as the in-memory assembly,
        // applied per shard (thread count never changes bits anyway)
        let mv = |n: usize| if n >= 8192 { self.threads } else { 1 };
        let bias = if m_count > 0.0 {
            let mut zky_parts = Vec::with_capacity(ne);
            let mut ysum_parts = Vec::with_capacity(ne);
            for (ei, eng) in self.engines.iter().enumerate() {
                let ke = eng.backend.matvec(&ebars[ei], mv(eng.n));
                zky_parts.push(zys[ei].iter().zip(ke.iter()).map(|(a, b)| a * b).sum::<f64>());
                ysum_parts
                    .push(eng.y.iter().zip(ebars[ei].iter()).map(|(yi, e)| yi * e).sum::<f64>());
            }
            -(fold_sum(&zky_parts) - fold_sum(&ysum_parts)) / m_count
        } else {
            // no margin SVs anywhere: average y − f over the SVs
            let mut acc_parts = Vec::with_capacity(ne);
            let mut cnt_parts = Vec::with_capacity(ne);
            for (ei, eng) in self.engines.iter().enumerate() {
                let f = eng.backend.matvec(&zys[ei], mv(eng.n));
                let mut acc = 0.0;
                let mut cnt = 0.0;
                for i in 0..eng.n {
                    if out.z[ei][i] > sv_tol {
                        acc += eng.y[i] - f[i];
                        cnt += 1.0;
                    }
                }
                acc_parts.push(acc);
                cnt_parts.push(cnt);
            }
            let cnt = fold_sum(&cnt_parts);
            if cnt > 0.0 {
                fold_sum(&acc_parts) / cnt
            } else {
                0.0
            }
        };

        // SVs: reload each shard's raw rows, select tree-order SV rows
        // through the composed (perm ∘ sv_idx) index in one pass
        let mut sv_parts: Vec<Points> = Vec::with_capacity(ne);
        let mut alpha_y = Vec::new();
        for (ei, eng) in self.engines.iter().enumerate() {
            let sv_idx: Vec<usize> =
                (0..eng.n).filter(|&i| out.z[ei][i] > sv_tol).collect();
            let ds = shards.load_shard(eng.shard, self.repr)?;
            let composed: Vec<usize> = sv_idx.iter().map(|&i| eng.perm[i]).collect();
            sv_parts.push(ds.x.select_rows(&composed));
            alpha_y.extend(sv_idx.iter().map(|&i| zys[ei][i]));
        }
        let sv = concat_points(sv_parts);
        self.phases.add("sv-extract", sv_timer.elapsed());

        Ok(SvmModel { sv, alpha_y, bias, kernel: self.kernel, c, labels: self.labels })
    }
}

/// Row-concatenate shard SV blocks. All parts share one representation
/// (the manifest's global Repr decision); a single part is returned
/// verbatim so the K = 1 path stays bit-identical.
fn concat_points(mut parts: Vec<Points>) -> Points {
    if parts.len() == 1 {
        return parts.pop().expect("one part");
    }
    let cols = parts.first().map(|p| p.cols()).unwrap_or(0);
    let rows: usize = parts.iter().map(|p| p.rows()).sum();
    let sparse = parts.first().map(|p| p.is_sparse()).unwrap_or(false);
    debug_assert!(parts.iter().all(|p| p.is_sparse() == sparse && p.cols() == cols));
    if sparse {
        let nnz: usize = parts.iter().map(|p| p.nnz()).sum();
        let mut indptr = Vec::with_capacity(rows + 1);
        indptr.push(0usize);
        let mut indices = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        for p in &parts {
            let Points::Sparse(s) = p else { unreachable!("repr is uniform across shards") };
            for i in 0..s.rows() {
                let (ci, vi) = s.row(i);
                indices.extend_from_slice(ci);
                vals.extend_from_slice(vi);
                indptr.push(indices.len());
            }
        }
        Points::Sparse(crate::data::CsrMat::new(rows, cols, indptr, indices, vals))
    } else {
        let mut m = Mat::zeros(rows, cols);
        let mut r = 0;
        for p in &parts {
            let Points::Dense(d) = p else { unreachable!("repr is uniform across shards") };
            for i in 0..d.rows() {
                m.row_mut(r).copy_from_slice(d.row(i));
                r += 1;
            }
        }
        Points::Dense(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::libsvm::write_file;
    use crate::data::shard::write_shards;
    use crate::data::synth;
    use crate::svm::predict;
    use crate::util::prng::Rng;
    use std::path::PathBuf;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("hss_svm_consensus_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn setup(dir: &std::path::Path, n: usize, k: usize) -> (ShardSet, Dataset) {
        let mut rng = Rng::new(41);
        let ds = synth::blobs(n + n / 2, 4, 4, 0.5, &mut rng);
        let (train, test) = ds.split_at(n);
        let src = dir.join("train.libsvm");
        write_file(&train, &src).unwrap();
        write_shards(&src, dir.join(format!("s{k}")), k).unwrap();
        let set = ShardSet::open(dir.join(format!("s{k}"))).unwrap();
        (set, test)
    }

    fn params() -> (HssParams, AdmmParams) {
        let mut hp = HssParams::low_accuracy();
        hp.leaf_size = 32;
        (hp, AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 })
    }

    #[test]
    fn consensus_classifies_blobs() {
        let dir = tmpdir("acc");
        let (set, test) = setup(&dir, 400, 4);
        let (hp, ap) = params();
        let kernel = Kernel::Gaussian { h: 1.5 };
        let (tr, stats) = ConsensusTrainer::build(&set, Repr::Auto, kernel, &hp, ap, 2).unwrap();
        assert_eq!(stats.resident_shards, 4);
        assert_eq!(stats.rows, 400);
        assert!(stats.hss_memory_bytes > 0);
        let (model, out) = tr.train_c(&set, 1.0).unwrap();
        assert_eq!(out.z.len(), 4);
        assert!(out.primal.len() == 10 && out.dual.len() == 10);
        let acc = predict::accuracy(&model, &test, 2);
        assert!(acc > 0.8, "consensus blobs accuracy {acc}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn per_shard_x_satisfies_global_equality() {
        // the consensus step exists to enforce yᵀx = 0 GLOBALLY: the
        // concatenated x must satisfy it even though no shard's local
        // block does on its own
        let dir = tmpdir("eq");
        let (set, _) = setup(&dir, 300, 3);
        let (hp, ap) = params();
        let (tr, _) =
            ConsensusTrainer::build(&set, Repr::Auto, Kernel::Gaussian { h: 1.5 }, &hp, ap, 1)
                .unwrap();
        let out = tr.train_grid(&[1.0]).pop().unwrap();
        let mut ytx = 0.0;
        for (ei, eng) in tr.engines.iter().enumerate() {
            for i in 0..eng.n {
                ytx += eng.y[i] * out.x[ei][i];
            }
        }
        assert!(ytx.abs() < 1e-8, "global yᵀx = {ytx}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn grid_lockstep_matches_single_c_runs() {
        let dir = tmpdir("grid");
        let (set, _) = setup(&dir, 240, 3);
        let (hp, ap) = params();
        let (tr, _) =
            ConsensusTrainer::build(&set, Repr::Auto, Kernel::Gaussian { h: 1.5 }, &hp, ap, 2)
                .unwrap();
        let cs = [0.1, 1.0, 10.0];
        let grid = tr.train_grid(&cs);
        for (j, &c) in cs.iter().enumerate() {
            let single = tr.train_grid(&[c]).pop().unwrap();
            assert_eq!(grid[j].z, single.z, "z mismatch at C={c}");
            assert_eq!(grid[j].mu, single.mu, "mu mismatch at C={c}");
            assert_eq!(grid[j].primal, single.primal, "primal mismatch at C={c}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn warm_start_reaches_similar_iterates_faster() {
        let dir = tmpdir("warm");
        let (set, _) = setup(&dir, 200, 2);
        let (hp, mut ap) = params();
        ap.max_it = 30;
        let (tr, _) =
            ConsensusTrainer::build(&set, Repr::Auto, Kernel::Gaussian { h: 1.5 }, &hp, ap, 1)
                .unwrap();
        let cold = tr.train_grid(&[1.0]).pop().unwrap();
        // warm-started from the converged state, the first-iteration
        // primal residual must be far below the cold run's peak
        let warm = tr.train_grid_warm(&[1.0], Some(&cold)).pop().unwrap();
        let cold_peak = cold.primal.iter().cloned().fold(0.0f64, f64::max);
        assert!(cold_peak > 0.0);
        assert!(
            warm.primal[0] < cold_peak * 0.5,
            "warm first residual {} vs cold peak {cold_peak}",
            warm.primal[0]
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn single_row_shards_use_dense_fallback() {
        let dir = tmpdir("tiny");
        let mut rng = Rng::new(43);
        let ds = synth::blobs(9, 3, 2, 0.4, &mut rng);
        let src = dir.join("tiny.libsvm");
        write_file(&ds, &src).unwrap();
        // K = 8 over 9 rows: shard 0 has 2 rows, shards 1..8 have 1
        write_shards(&src, dir.join("s8"), 8).unwrap();
        let set = ShardSet::open(dir.join("s8")).unwrap();
        let (hp, ap) = params();
        let (tr, stats) =
            ConsensusTrainer::build(&set, Repr::Auto, Kernel::Gaussian { h: 1.0 }, &hp, ap, 1)
                .unwrap();
        assert_eq!(stats.resident_shards, 8);
        let (model, _) = tr.train_c(&set, 1.0).unwrap();
        assert!(model.bias.is_finite());
        assert!(model.n_sv() <= 9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn shard_seeds_are_shard_major_and_stable() {
        let base = 0xB10C;
        assert_eq!(shard_seed(base, 0), base, "shard 0 keeps the base seed");
        let s1 = shard_seed(base, 1);
        let s2 = shard_seed(base, 2);
        assert_ne!(s1, base);
        assert_ne!(s1, s2);
        // pure function of (base, shard): recomputing gives the same
        assert_eq!(shard_seed(base, 2), s2);
    }
}
