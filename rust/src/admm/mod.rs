//! Closed-form ADMM for the SVM dual (Algorithms 2–3 of the paper).
//!
//! Problem (1):  min ½ xᵀYKYx − eᵀx  s.t. yᵀx = 0, 0 ≤ x ≤ C.
//! The splitting x − z = 0 gives three closed-form steps per iteration:
//!
//! * x-update: one solve with K_β = K + βI (the only expensive step —
//!   served by the cached ULV factorization),
//! * z-update: box projection Π_{[0,C]},
//! * multiplier update.
//!
//! `w = Y K_β⁻¹ e` and `w₁ = eᵀK_β⁻¹e` are precomputed once per (h, β)
//! and shared by every C of the grid search.

pub mod consensus;
pub mod solver;

pub use consensus::{ConsensusOutput, ConsensusStats, ConsensusTrainer};
pub use solver::{AdmmHistory, AdmmOutput, AdmmParams, AdmmSolver, ShiftedSolve};
