//! Approximate nearest neighbours (ANN).
//!
//! HSS-ANN compression [Chávez et al. 2020] selects, for every point, the
//! columns of its dominating approximate nearest neighbours to seed the
//! low-rank bases — for the Gaussian kernel "nearest in distance" is
//! exactly "largest kernel entry". We implement the classic randomized
//! projection-forest scheme of Xiao & Biros [47]: several random-direction
//! recursive bisections put near points in shared buckets, brute force
//! inside buckets, then a neighbour-of-neighbour refinement sweep.

// No raw-pointer tricks belong in this module tree (see DESIGN.md §11).
#![forbid(unsafe_code)]

use crate::data::Dataset;
use crate::util::prng::Rng;
use crate::util::threadpool;

/// k-nearest-neighbour lists: `neighbors[i]` holds up to k (index, dist²)
/// pairs sorted by increasing distance, excluding `i` itself.
pub struct KnnLists {
    pub k: usize,
    pub neighbors: Vec<Vec<(usize, f64)>>,
}

/// Parameters for the projection-forest search.
#[derive(Clone, Copy, Debug)]
pub struct AnnParams {
    /// Neighbours per point.
    pub k: usize,
    /// Number of random-projection trees.
    pub trees: usize,
    /// Brute-force bucket size.
    pub bucket: usize,
    /// Neighbour-of-neighbour refinement sweeps.
    pub refine: usize,
}

impl Default for AnnParams {
    fn default() -> Self {
        AnnParams { k: 64, trees: 4, bucket: 96, refine: 1 }
    }
}

/// Compute approximate kNN lists for all points.
pub fn knn(ds: &Dataset, params: AnnParams, threads: usize, rng: &mut Rng) -> KnnLists {
    let n = ds.len();
    let k = params.k.min(n.saturating_sub(1));
    let mut best: Vec<NeighborHeap> = (0..n).map(|_| NeighborHeap::new(k)).collect();

    // --- projection forest ---
    for t in 0..params.trees {
        let mut tree_rng = rng.fork(t as u64);
        let mut idx: Vec<usize> = (0..n).collect();
        let mut buckets: Vec<(usize, usize)> = Vec::new();
        bisect(ds, &mut idx, 0, n, params.bucket, &mut tree_rng, &mut buckets);
        // brute force within each bucket (parallel over buckets)
        // chunk = 1: a bucket is O(bucket²) distance evaluations
        let results: Vec<Vec<(usize, usize, f64)>> =
            threadpool::parallel_map(threads, buckets.len(), 1, |b| {
                let (lo, hi) = buckets[b];
                let ids = &idx[lo..hi];
                let mut out = Vec::with_capacity(ids.len() * 4);
                for (a_pos, &a) in ids.iter().enumerate() {
                    for &b_id in ids.iter().skip(a_pos + 1) {
                        let d2 = ds.x.dist2_rows(a, &ds.x, b_id);
                        out.push((a, b_id, d2));
                    }
                }
                out
            });
        for pairs in results {
            for (a, b, d2) in pairs {
                best[a].push(b, d2);
                best[b].push(a, d2);
            }
        }
    }

    // --- neighbour-of-neighbour refinement ---
    // Cost control: the full sweep is O(n·k²); for large k (the paper's
    // hss_approximate_neighbors=512 setting) only the `fanout` closest
    // neighbours expand, which keeps refinement O(n·fanout²) while still
    // bridging projection-tree bucket boundaries.
    let fanout = k.min(24);
    for _ in 0..params.refine {
        let snapshot: Vec<Vec<usize>> = best.iter().map(|h| h.closest(fanout)).collect();
        // per-point expansion is cheap → chunk 32 amortizes the atomic
        // fetch across a cache-friendly run of points
        let updates: Vec<Vec<(usize, f64)>> = threadpool::parallel_map(threads, n, 32, |i| {
            let mut cand: Vec<usize> = Vec::new();
            for &j in &snapshot[i] {
                for &jj in &snapshot[j] {
                    if jj != i {
                        cand.push(jj);
                    }
                }
            }
            cand.sort_unstable();
            cand.dedup();
            cand.into_iter()
                .map(|c| (c, ds.x.dist2_rows(i, &ds.x, c)))
                .collect()
        });
        for (i, ups) in updates.into_iter().enumerate() {
            for (c, d2) in ups {
                best[i].push(c, d2);
            }
        }
    }

    let neighbors = best.into_iter().map(|h| h.into_sorted()).collect();
    KnnLists { k, neighbors }
}

/// Exact kNN by brute force — O(n²), test oracle and small-n path.
pub fn knn_exact(ds: &Dataset, k: usize, threads: usize) -> KnnLists {
    let n = ds.len();
    let k = k.min(n.saturating_sub(1));
    // an O(n) scan per point is still small for the n this path serves
    // (n ≤ 512) → chunk 16
    let neighbors = threadpool::parallel_map(threads, n, 16, |i| {
        let mut d: Vec<(usize, f64)> = (0..n)
            .filter(|&j| j != i)
            .map(|j| (j, ds.x.dist2_rows(i, &ds.x, j)))
            .collect();
        d.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        d.truncate(k);
        d
    });
    KnnLists { k, neighbors }
}

/// Recall of `approx` against exact lists (fraction of true neighbours
/// found) — the quality metric reported in ANN papers.
pub fn recall(approx: &KnnLists, exact: &KnnLists) -> f64 {
    let n = approx.neighbors.len();
    let mut hit = 0usize;
    let mut total = 0usize;
    for i in 0..n {
        let truth: std::collections::HashSet<usize> =
            exact.neighbors[i].iter().map(|&(j, _)| j).collect();
        for &(j, _) in &approx.neighbors[i] {
            if truth.contains(&j) {
                hit += 1;
            }
        }
        total += truth.len();
    }
    if total == 0 {
        1.0
    } else {
        hit as f64 / total as f64
    }
}

/// Bounded max-heap keeping the k smallest distances, deduplicated.
/// O(log k) pushes — the k=512 setting of Table 5 makes linear scans
/// (O(k) per push) the dominant cost otherwise.
struct NeighborHeap {
    cap: usize,
    heap: std::collections::BinaryHeap<(F64Ord, usize)>, // max by distance
    members: std::collections::HashSet<usize>,
}

/// Total-order f64 wrapper for the heap key.
#[derive(PartialEq)]
struct F64Ord(f64);

impl Eq for F64Ord {}

impl PartialOrd for F64Ord {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for F64Ord {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

impl NeighborHeap {
    fn new(cap: usize) -> Self {
        NeighborHeap {
            cap,
            heap: std::collections::BinaryHeap::with_capacity(cap + 1),
            members: std::collections::HashSet::with_capacity(cap * 2),
        }
    }

    fn push(&mut self, idx: usize, d2: f64) {
        if self.cap == 0 || self.members.contains(&idx) {
            return;
        }
        if self.heap.len() < self.cap {
            self.heap.push((F64Ord(d2), idx));
            self.members.insert(idx);
        } else if d2 < self.heap.peek().unwrap().0 .0 {
            let (_, worst_idx) = self.heap.pop().unwrap();
            self.members.remove(&worst_idx);
            self.heap.push((F64Ord(d2), idx));
            self.members.insert(idx);
        }
    }

    /// (index, dist²) pairs sorted by increasing distance.
    fn into_sorted(self) -> Vec<(usize, f64)> {
        let mut v: Vec<(usize, f64)> =
            self.heap.into_iter().map(|(d, i)| (i, d.0)).collect();
        v.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());
        v
    }

    /// Up to `limit` closest indices (for the refinement fan-out).
    fn closest(&self, limit: usize) -> Vec<usize> {
        let mut v: Vec<(f64, usize)> =
            self.heap.iter().map(|&(F64Ord(d), i)| (d, i)).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        v.into_iter().take(limit).map(|(_, i)| i).collect()
    }
}

/// Random-projection bisection into buckets of ≤ `bucket` points.
fn bisect(
    ds: &Dataset,
    idx: &mut [usize],
    lo: usize,
    hi: usize,
    bucket: usize,
    rng: &mut Rng,
    out: &mut Vec<(usize, usize)>,
) {
    let len = hi - lo;
    if len <= bucket {
        out.push((lo, hi));
        return;
    }
    let dim = ds.dim();
    let dir: Vec<f64> = (0..dim).map(|_| rng.gauss()).collect();
    let mut proj: Vec<(f64, usize)> = idx[lo..hi]
        .iter()
        .map(|&i| (ds.x.dot_dense_vec(i, &dir) + 1e-12 * rng.gauss(), i))
        .collect();
    proj.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (t, &(_, i)) in proj.iter().enumerate() {
        idx[lo + t] = i;
    }
    let mid = lo + len / 2;
    bisect(ds, idx, lo, mid, bucket, rng, out);
    bisect(ds, idx, mid, hi, bucket, rng, out);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn exact_knn_sorted_and_correct_on_line() {
        // points on a line: neighbours are adjacent indices
        let x = crate::linalg::Mat::from_fn(10, 1, |i, _| i as f64);
        let y = (0..10).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
        let ds = Dataset::new("line", x, y);
        let knn = knn_exact(&ds, 2, 1);
        assert_eq!(knn.neighbors[0][0].0, 1);
        assert_eq!(knn.neighbors[0][1].0, 2);
        assert_eq!(knn.neighbors[5][0].0 .min(knn.neighbors[5][1].0), 4);
        for l in &knn.neighbors {
            assert!(l.windows(2).all(|w| w[0].1 <= w[1].1));
        }
    }

    #[test]
    fn approximate_recall_is_high_on_clustered_data() {
        let mut rng = Rng::new(10);
        let ds = synth::blobs(600, 8, 6, 0.3, &mut rng);
        let exact = knn_exact(&ds, 10, 2);
        let approx = knn(
            &ds,
            AnnParams { k: 10, trees: 6, bucket: 64, refine: 2 },
            2,
            &mut rng,
        );
        let r = recall(&approx, &exact);
        assert!(r > 0.9, "ANN recall too low: {r}");
    }

    #[test]
    fn lists_exclude_self_and_dedup() {
        let mut rng = Rng::new(11);
        let ds = synth::blobs(200, 4, 3, 0.4, &mut rng);
        let res = knn(&ds, AnnParams { k: 8, trees: 3, bucket: 32, refine: 1 }, 1, &mut rng);
        for (i, l) in res.neighbors.iter().enumerate() {
            assert!(l.iter().all(|&(j, _)| j != i), "self in list {i}");
            let set: std::collections::HashSet<usize> = l.iter().map(|&(j, _)| j).collect();
            assert_eq!(set.len(), l.len(), "dup in list {i}");
            assert!(l.len() <= 8);
        }
    }

    #[test]
    fn sparse_exact_knn_matches_dense_bitwise() {
        // dist2 walks indices ascending with one accumulator in every
        // representation arm, so CSR distances are bit-for-bit equal to
        // dense ones and the neighbour lists must match exactly
        let mut rng = Rng::new(13);
        let ds = synth::blobs(120, 5, 3, 0.3, &mut rng);
        let sp = Dataset::new(
            "sp",
            crate::data::CsrMat::from_dense(ds.x.dense()),
            ds.y.clone(),
        );
        let a = knn_exact(&ds, 6, 2);
        let b = knn_exact(&sp, 6, 2);
        for (la, lb) in a.neighbors.iter().zip(b.neighbors.iter()) {
            assert_eq!(la, lb);
        }
    }

    #[test]
    fn k_larger_than_n_is_clamped() {
        let mut rng = Rng::new(12);
        let ds = synth::blobs(5, 2, 2, 0.1, &mut rng);
        let res = knn(&ds, AnnParams { k: 64, trees: 2, bucket: 8, refine: 1 }, 1, &mut rng);
        assert_eq!(res.k, 4);
        for l in &res.neighbors {
            assert!(l.len() <= 4);
        }
    }

    use crate::data::Dataset;
    use crate::util::prng::Rng;
}
