//! End-to-end contracts of the sharded consensus-ADMM trainer
//! (`hss_svm::admm::consensus`):
//!
//! * K = 1 is the in-memory trainer, bit-for-bit (same model file);
//! * the trained model is a pure function of the shard count — bitwise
//!   identical across threads {1, 2, 8} for each K, and across a
//!   re-shard + re-train of the same source;
//! * ragged last shards and single-row shards (the dense fallback
//!   backend) train and classify;
//! * the sharded CLI path persists through the standard v1.1 model
//!   format, so predict works unchanged.
//!
//! Sizes are kept small: this runs under tier-1 `cargo test`.

use hss_svm::admm::{AdmmParams, ConsensusTrainer};
use hss_svm::data::libsvm::{self, Repr};
use hss_svm::data::{synth, Dataset, ShardSet};
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::train::train_hss_svm;
use hss_svm::svm::{persist, predict};
use hss_svm::util::prng::Rng;
use std::path::{Path, PathBuf};

fn work_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("hss_svm_consensus_e2e_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn stage(dir: &Path, n: usize, test_n: usize, seed: u64) -> (PathBuf, Dataset) {
    let mut rng = Rng::new(seed);
    let ds = synth::blobs(n + test_n, 5, 4, 0.45, &mut rng);
    let (train, test) = ds.split_at(n);
    let src = dir.join("train.libsvm");
    libsvm::write_file(&train, &src).unwrap();
    (src, test)
}

fn hss_params() -> HssParams {
    let mut p = HssParams::low_accuracy();
    p.leaf_size = 32;
    p
}

fn admm_params() -> AdmmParams {
    AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 }
}

/// Shard (or reuse), train at the given thread count, persist, return
/// the model file bytes.
fn sharded_model_bytes(src: &Path, dir: &Path, k: usize, threads: usize) -> Vec<u8> {
    let set = ShardSet::open_or_create(src, dir.join(format!("s{k}")), k).unwrap();
    let (trainer, _) = ConsensusTrainer::build(
        &set,
        Repr::Auto,
        Kernel::Gaussian { h: 1.5 },
        &hss_params(),
        admm_params(),
        threads,
    )
    .unwrap();
    let (model, _) = trainer.train_c(&set, 1.0).unwrap();
    let path = dir.join(format!("m_k{k}_t{threads}.model"));
    persist::save(&model, &path).unwrap();
    std::fs::read(&path).unwrap()
}

#[test]
fn k1_is_the_in_memory_trainer_bitwise() {
    let dir = work_dir("k1");
    let (src, _) = stage(&dir, 160, 40, 171);
    let sharded = sharded_model_bytes(&src, &dir, 1, 2);

    // the in-memory reference: same raw (unscaled) file, same params
    let ds = libsvm::read_file_with(&src, None, Repr::Auto).unwrap();
    let (model, _) = train_hss_svm(
        &ds,
        Kernel::Gaussian { h: 1.5 },
        &hss_params(),
        &admm_params(),
        1.0,
        2,
    )
    .unwrap();
    let ref_path = dir.join("inmem.model");
    persist::save(&model, &ref_path).unwrap();
    let inmem = std::fs::read(&ref_path).unwrap();

    assert_eq!(sharded, inmem, "K = 1 sharded model differs from the in-memory trainer");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn model_is_a_pure_function_of_shard_count() {
    // the (shards × threads) grid: for each K the model must be
    // bitwise-identical across thread counts — including a count
    // exceeding the shard count
    let dir = work_dir("grid");
    let (src, test) = stage(&dir, 200, 60, 172);
    for k in [2usize, 3] {
        let reference = sharded_model_bytes(&src, &dir, k, 1);
        for threads in [2usize, 8] {
            let got = sharded_model_bytes(&src, &dir, k, threads);
            assert_eq!(
                got, reference,
                "K = {k}: model at {threads} threads differs from 1 thread"
            );
        }
        // and the model actually classifies
        let model = persist::load(dir.join(format!("m_k{k}_t1.model"))).unwrap();
        let acc = predict::accuracy(&model, &test, 2);
        assert!(acc > 0.8, "K = {k} accuracy {acc}");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn reshard_and_retrain_is_bitwise_stable() {
    let dir = work_dir("reshard");
    let (src, _) = stage(&dir, 150, 30, 173);
    let first = sharded_model_bytes(&src, &dir, 3, 2);
    // drop the shard directory entirely: open_or_create must re-shard
    // from the source and reach the exact same model
    std::fs::remove_dir_all(dir.join("s3")).unwrap();
    let second = sharded_model_bytes(&src, &dir, 3, 2);
    assert_eq!(first, second, "re-shard + re-train changed the model");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn ragged_last_shards_train_and_classify() {
    // n = 101 over K = 4: round-robin gives rows [26, 25, 25, 25]
    let dir = work_dir("ragged");
    let (src, test) = stage(&dir, 101, 40, 174);
    let set = ShardSet::open_or_create(&src, dir.join("s4"), 4).unwrap();
    let m = set.manifest();
    assert_eq!(m.shard_rows, vec![26, 25, 25, 25]);
    let (trainer, stats) = ConsensusTrainer::build(
        &set,
        Repr::Auto,
        Kernel::Gaussian { h: 1.5 },
        &hss_params(),
        admm_params(),
        2,
    )
    .unwrap();
    assert_eq!(stats.resident_shards, 4);
    assert_eq!(trainer.n(), 101);
    let (model, _) = trainer.train_c(&set, 1.0).unwrap();
    let acc = predict::accuracy(&model, &test, 2);
    assert!(acc > 0.75, "ragged-shard accuracy {acc}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn single_row_shards_use_the_dense_fallback() {
    // K = 8 over 9 rows: one 2-row shard, seven 1-row shards — the
    // 1-row shards cannot build a cluster tree and must fall back to
    // the exact dense backend; the run must still be thread-invariant
    let dir = work_dir("tiny");
    let (src, _) = stage(&dir, 9, 6, 175);
    let b1 = sharded_model_bytes(&src, &dir, 8, 1);
    let b2 = sharded_model_bytes(&src, &dir, 8, 2);
    assert_eq!(b1, b2, "single-row-shard model differs across threads");
    let model = persist::load(dir.join("m_k8_t1.model")).unwrap();
    assert!(model.bias.is_finite());
    assert!(model.n_sv() <= 9);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sharded_models_predict_through_the_standard_path() {
    // persistence rides the v1.1 format: load_any + decision_function
    // treat a consensus model exactly like an in-memory one
    let dir = work_dir("persist");
    let (src, test) = stage(&dir, 120, 30, 176);
    let bytes = sharded_model_bytes(&src, &dir, 4, 2);
    let path = dir.join("roundtrip.model");
    std::fs::write(&path, &bytes).unwrap();
    match persist::load_any(&path).unwrap() {
        hss_svm::svm::AnyModel::Binary(m) => {
            let f = predict::decision_function(&m, &test.x, 2);
            assert_eq!(f.len(), test.len());
            assert!(f.iter().all(|v| v.is_finite()));
        }
        _ => panic!("sharded training must persist a binary v1.1 model"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
