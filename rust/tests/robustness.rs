//! Robustness and edge-case integration tests: degenerate inputs,
//! non-default kernels, extreme hyperparameters, failure injection.

use hss_svm::admm::{AdmmParams, AdmmSolver};
use hss_svm::data::{libsvm, synth, Dataset};
use hss_svm::hss::compress::compress;
use hss_svm::hss::ulv::UlvFactor;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::linalg::Mat;
use hss_svm::svm::{predict, train::train_hss_svm, HssSvmTrainer};
use hss_svm::util::prng::Rng;
use hss_svm::util::testkit;

#[test]
fn polynomial_kernel_full_pipeline() {
    let mut rng = Rng::new(401);
    let train = synth::blobs(300, 4, 2, 0.15, &mut rng);
    let test = synth::blobs(150, 4, 2, 0.15, &mut {
        let mut r = Rng::new(401);
        r
    });
    let kernel = Kernel::Polynomial { degree: 2, c: 1.0 };
    let c = compress(&train, &kernel, &HssParams::near_exact(), 1);
    // HSS must reproduce the polynomial kernel too (structure-agnostic)
    let want = kernel.gram(&c.pds.x);
    let got = hss_svm::hss::matvec::to_dense(&c.hss);
    let mut d = got;
    d.axpy(-1.0, &want);
    assert!(d.fro() / want.fro() < 1e-6, "poly HSS error {}", d.fro() / want.fro());

    let (model, _) = train_hss_svm(
        &train,
        kernel,
        &HssParams::near_exact(),
        &AdmmParams { beta: 10.0, max_it: 20, relax: 1.0, tol: 0.0 },
        1.0,
        1,
    )
    .unwrap();
    let acc = predict::accuracy(&model, &test, 1);
    assert!(acc > 0.9, "poly accuracy {acc}");
}

#[test]
fn beta_staging_values_all_converge() {
    let mut rng = Rng::new(402);
    let train = synth::blobs(400, 5, 4, 0.3, &mut rng);
    let trainer = HssSvmTrainer::compress(
        &train,
        Kernel::Gaussian { h: 1.0 },
        &HssParams::low_accuracy(),
        1,
    );
    // the paper's three staged β values must all produce working models
    for beta in [1e2, 1e3, 1e4] {
        let ulv = trainer.factor(beta).unwrap();
        let (model, out) = trainer.train_c(&ulv, &AdmmParams { beta, max_it: 10, relax: 1.0, tol: 0.0 }, 1.0);
        assert!(out.z.iter().all(|v| v.is_finite()));
        let acc = predict::accuracy(&model, &train, 1);
        assert!(acc > 0.7, "beta={beta} train accuracy {acc}");
    }
}

#[test]
fn extreme_c_values_stay_feasible() {
    let mut rng = Rng::new(403);
    let train = synth::two_moons(200, 0.08, &mut rng);
    let trainer =
        HssSvmTrainer::compress(&train, Kernel::Gaussian { h: 0.3 }, &HssParams::near_exact(), 1);
    let ulv = trainer.factor(10.0).unwrap();
    for c in [1e-6, 1e6] {
        let (model, out) = trainer.train_c(&ulv, &AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 }, c);
        assert!(out.z.iter().all(|&z| (0.0..=c + 1e-9).contains(&z)));
        assert!(model.bias.is_finite());
    }
}

#[test]
fn single_class_training_does_not_panic() {
    let mut rng = Rng::new(404);
    let x = Mat::gauss(60, 3, &mut rng);
    let ds = Dataset::new("onesided", x, vec![1.0; 60]);
    // yᵀx = 0 with all-positive labels forces x ≈ 0; must not panic
    let result = train_hss_svm(
        &ds,
        Kernel::Gaussian { h: 1.0 },
        &HssParams::near_exact(),
        &AdmmParams { beta: 10.0, max_it: 5, relax: 1.0, tol: 0.0 },
        1.0,
        1,
    );
    let (model, _) = result.unwrap();
    assert!(model.bias.is_finite());
}

#[test]
fn tiny_beta_solve_is_still_accurate() {
    // β → 0 stresses the ULV elimination (K̃ is only PSD); near-exact
    // compression keeps K̃ ≈ K PD-ish, tiny shift must still solve well
    let mut rng = Rng::new(405);
    let ds = synth::blobs(150, 3, 3, 0.4, &mut rng);
    let kernel = Kernel::Gaussian { h: 0.4 }; // small h → well-conditioned K
    let c = compress(&ds, &kernel, &HssParams::near_exact(), 1);
    let beta = 1e-3;
    let ulv = UlvFactor::new(&c.hss, beta).unwrap();
    let want: Vec<f64> = (0..150).map(|_| rng.gauss()).collect();
    let b = hss_svm::hss::matvec::matvec_shifted(&c.hss, beta, &want);
    let got = ulv.solve(&b);
    testkit::assert_allclose(&got, &want, 1e-5);
}

#[test]
fn admm_solver_reuse_is_deterministic() {
    let mut rng = Rng::new(406);
    let train = synth::circles(200, 0.05, &mut rng);
    let trainer =
        HssSvmTrainer::compress(&train, Kernel::Gaussian { h: 0.4 }, &HssParams::near_exact(), 1);
    let ulv = trainer.factor(10.0).unwrap();
    let solver = AdmmSolver::new(&ulv, &trainer.y, AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 });
    let a = solver.run(1.0);
    let b = solver.run(1.0);
    assert_eq!(a.z, b.z, "ADMM must be deterministic");
    // a C small enough to clip some coordinates changes the iterates
    let max_z = a.z.iter().cloned().fold(0.0f64, f64::max);
    let c = solver.run(max_z * 0.25);
    assert_ne!(a.z, c.z);
}

#[test]
fn libsvm_file_to_model_roundtrip() {
    let mut rng = Rng::new(407);
    let ds = synth::two_moons(300, 0.08, &mut rng);
    let dir = std::env::temp_dir().join("hss_svm_rt_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("moons.libsvm");
    libsvm::write_file(&ds, &path).unwrap();
    let back = libsvm::read_file(&path, None).unwrap();
    assert_eq!(back.len(), 300);
    let (model, _) = train_hss_svm(
        &back,
        Kernel::Gaussian { h: 0.3 },
        &HssParams::near_exact(),
        &AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
        10.0,
        1,
    )
    .unwrap();
    let acc = predict::accuracy(&model, &back, 1);
    assert!(acc > 0.95, "roundtrip accuracy {acc}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn compression_scales_subquadratically_in_kernel_evals() {
    // O(r² d) construction: kernel-eval count per point must not grow
    // linearly with n (that would be O(n²) total)
    let mut rng = Rng::new(408);
    let kernel = Kernel::Gaussian { h: 1.5 };
    let mut per_point = Vec::new();
    for &n in &[1000usize, 4000] {
        let ds = synth::blobs(n, 6, 5, 0.3, &mut rng);
        let mut p = HssParams::low_accuracy();
        p.ann_neighbors = 16;
        p.oversample = 16;
        let c = compress(&ds, &kernel, &p, 1);
        per_point.push(c.stats.kernel_evals as f64 / n as f64);
    }
    // allow some growth (deeper tree), but far below 4x
    assert!(
        per_point[1] < per_point[0] * 2.5,
        "kernel evals/point grew {:.0} → {:.0} (not matrix-free?)",
        per_point[0],
        per_point[1]
    );
}

#[test]
fn predict_on_mismatched_dims_panics() {
    let mut rng = Rng::new(409);
    let model = hss_svm::svm::SvmModel {
        sv: Mat::gauss(5, 3, &mut rng).into(),
        alpha_y: vec![1.0; 5],
        bias: 0.0,
        kernel: Kernel::Gaussian { h: 1.0 },
        c: 1.0,
        labels: hss_svm::data::DEFAULT_LABEL_PAIR,
    };
    let bad = hss_svm::data::Points::Dense(Mat::gauss(4, 7, &mut rng));
    let result = std::panic::catch_unwind(|| predict::decision_function(&model, &bad, 1));
    assert!(result.is_err(), "dimension mismatch must be caught");
}
