//! Determinism of the multilevel schedule (DESIGN.md §15): every piece
//! of the coarse-to-fine pipeline — screening, representative selection,
//! the level schedule, and the trained models — is a pure function of
//! `(dataset, HssParams.seed, MultilevelParams)`. Thread counts and
//! repetition never change a bit. This mirrors
//! `tests/thread_invariance.rs` one layer up: the helpers are serial by
//! construction (ordered scans over `Vec<bool>` masks), and training
//! inherits the tree engine's bitwise contract.

use hss_svm::admm::AdmmParams;
use hss_svm::data::synth;
use hss_svm::hss::compress::preprocess;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::multilevel::{
    frontier_nodes, screen_extreme_points, select_representatives, MultilevelContext,
    MultilevelParams,
};
use hss_svm::util::prng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn fixture() -> (hss_svm::data::Dataset, HssParams) {
    let mut rng = Rng::new(60_601);
    let ds = synth::blobs(700, 5, 4, 0.3, &mut rng);
    let mut hp = HssParams::low_accuracy();
    hp.leaf_size = 32;
    (ds, hp)
}

#[test]
fn representative_selection_is_a_pure_function_of_tree_and_seed() {
    let (ds, hp) = fixture();
    // the preprocessing (tree + ANN) is itself thread-invariant, so the
    // same dataset + seed must give identical trees at every thread
    // count — and identical reps/screening on top of them
    let base = preprocess(&ds, &hp, 1);
    let base_keep = screen_extreme_points(&base.pds, &base.tree, 0.2);
    for t in THREAD_COUNTS {
        let pre = preprocess(&ds, &hp, t);
        assert_eq!(pre.tree.perm, base.tree.perm, "tree permutation differs at threads={t}");
        let keep = screen_extreme_points(&pre.pds, &pre.tree, 0.2);
        assert_eq!(keep, base_keep, "screening mask differs at threads={t}");
        for level in 0..pre.tree.depth() {
            assert_eq!(
                frontier_nodes(&pre.tree, level),
                frontier_nodes(&base.tree, level),
                "frontier differs at threads={t} level={level}"
            );
            assert_eq!(
                select_representatives(&pre.pds, &pre.tree, level, &keep),
                select_representatives(&base.pds, &base.tree, level, &base_keep),
                "representatives differ at threads={t} level={level}"
            );
        }
    }
    // repeated runs on the SAME preprocessing are trivially identical
    // only if no hidden state exists — pin that too
    let again = select_representatives(&base.pds, &base.tree, 3, &base_keep);
    assert_eq!(again, select_representatives(&base.pds, &base.tree, 3, &base_keep));
}

#[test]
fn full_schedule_and_models_repeat_bitwise() {
    let (ds, hp) = fixture();
    let kernel = Kernel::Gaussian { h: 1.0 };
    let admm = AdmmParams { beta: 100.0, max_it: 6, relax: 1.0, tol: 0.0 };
    let ml = MultilevelParams { screen_eps: 0.1, ..Default::default() };

    let runs: Vec<_> = (0..2)
        .map(|_| {
            let ctx = MultilevelContext::new(&ds, &hp, &ml, 2);
            let run = ctx.train_grid(kernel, &admm, &[0.5, 2.0]).unwrap();
            (ctx.pool_sizes(), run)
        })
        .collect();
    let (pools_a, run_a) = &runs[0];
    let (pools_b, run_b) = &runs[1];
    assert_eq!(pools_a, pools_b, "level schedule differs between identical runs");
    assert_eq!(run_a.levels.len(), run_b.levels.len());
    for (la, lb) in run_a.levels.iter().zip(run_b.levels.iter()) {
        assert_eq!(la.t_idx, lb.t_idx, "training set differs at level {}", la.level);
        assert_eq!(la.sv_idx, lb.sv_idx, "SV set differs at level {}", la.level);
    }
    for ((ma, oa), (mb, ob)) in run_a.results.iter().zip(run_b.results.iter()) {
        assert!(ma.sv == mb.sv, "SV coordinates differ between identical runs");
        assert_eq!(ma.alpha_y, mb.alpha_y, "alpha_y differs between identical runs");
        assert_eq!(ma.bias.to_bits(), mb.bias.to_bits(), "bias differs between identical runs");
        assert_eq!(oa.z, ob.z, "final z differs between identical runs");
    }
}
