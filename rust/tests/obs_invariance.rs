//! Observability passivity contract (DESIGN.md §14): tracing must
//! NEVER perturb computation. Models, residual histories and
//! predictions are **bit-for-bit identical** with the JSONL trace sink
//! on or off, at every thread count — events are emitted after the
//! parallel joins from already-computed values, so nothing may drift,
//! not even in the last ulp.
//!
//! The trace sink is process-global state, so every test that installs
//! one serializes on [`sink_lock`] (the same pattern as the unit tests
//! in `obs::trace`).

use hss_svm::admm::{AdmmParams, AdmmSolver};
use hss_svm::data::synth;
use hss_svm::hss::compress::compress;
use hss_svm::hss::ulv::UlvFactor;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::obs::{self, TraceEvent};
use hss_svm::svm::train::train_hss_svm;
use hss_svm::svm::{predict, SvmModel};
use hss_svm::util::prng::Rng;
use std::sync::{Arc, Mutex, OnceLock};

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn sink_lock() -> &'static Mutex<()> {
    static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
    LOCK.get_or_init(|| Mutex::new(()))
}

/// A writer the test can inspect after `disable()` drops the sink.
#[derive(Clone, Default)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl std::io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

impl SharedBuf {
    fn text(&self) -> String {
        String::from_utf8(self.0.lock().unwrap().clone()).unwrap()
    }
}

fn workload() -> hss_svm::data::Dataset {
    let mut rng = Rng::new(42_042);
    synth::blobs(600, 3, 4, 0.4, &mut rng)
}

fn train_once(ds: &hss_svm::data::Dataset, threads: usize) -> (SvmModel, Vec<f64>, Vec<f64>) {
    let hss = HssParams::low_accuracy();
    let ap = AdmmParams { beta: 100.0, max_it: 8, relax: 1.0, tol: 0.0 };
    let (model, stats) =
        train_hss_svm(ds, Kernel::Gaussian { h: 1.0 }, &hss, &ap, 1.0, threads).unwrap();
    (model, stats.primal, stats.dual)
}

fn assert_models_bitwise(a: &SvmModel, b: &SvmModel, label: &str) {
    assert_eq!(a.alpha_y, b.alpha_y, "{label}: alpha_y differs");
    assert_eq!(a.bias.to_bits(), b.bias.to_bits(), "{label}: bias differs");
    assert_eq!(a.n_sv(), b.n_sv(), "{label}: SV count differs");
}

#[test]
fn training_is_bitwise_invariant_under_tracing() {
    let _guard = sink_lock().lock().unwrap_or_else(|e| e.into_inner());
    let ds = workload();
    let test = {
        let mut rng = Rng::new(77);
        synth::blobs(200, 3, 4, 0.4, &mut rng)
    };
    for t in THREAD_COUNTS {
        // reference run: tracing off
        obs::trace::disable();
        assert!(!obs::enabled());
        let (m_off, primal_off, dual_off) = train_once(&ds, t);
        let f_off = predict::decision_function(&m_off, &test.x, t);

        // traced run: every event goes to a real sink
        let buf = SharedBuf::default();
        obs::trace::init_writer(Box::new(buf.clone()));
        assert!(obs::enabled());
        let (m_on, primal_on, dual_on) = train_once(&ds, t);
        let f_on = predict::decision_function(&m_on, &test.x, t);
        obs::trace::disable();

        assert_models_bitwise(&m_off, &m_on, &format!("threads={t}"));
        assert_eq!(primal_off, primal_on, "threads={t}: primal residual curve differs");
        assert_eq!(dual_off, dual_on, "threads={t}: dual residual curve differs");
        assert_eq!(f_off, f_on, "threads={t}: decision values differ");

        // the traced run produced a schema-valid, non-trivial stream
        let text = buf.text();
        let events: Vec<TraceEvent> = text
            .lines()
            .map(|l| TraceEvent::from_json(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e}")))
            .collect();
        assert!(
            events.iter().any(|e| matches!(e, TraceEvent::CompressDone { .. })),
            "threads={t}: no compress_done event"
        );
        assert!(
            events.iter().any(|e| matches!(e, TraceEvent::UlvFactor { .. })),
            "threads={t}: no ulv_factor event"
        );
        let iters =
            events.iter().filter(|e| matches!(e, TraceEvent::AdmmIter { .. })).count();
        assert_eq!(iters, 8, "threads={t}: one admm_iter per iteration");
        assert!(
            events.iter().any(|e| matches!(e, TraceEvent::AdmmDone { .. })),
            "threads={t}: no admm_done event"
        );
    }
}

#[test]
fn batched_grid_is_bitwise_invariant_under_tracing() {
    let _guard = sink_lock().lock().unwrap_or_else(|e| e.into_inner());
    let ds = workload();
    let compressed = compress(&ds, &Kernel::Gaussian { h: 1.0 }, &HssParams::low_accuracy(), 2);
    let beta = 100.0;
    let ap = AdmmParams { beta, max_it: 6, relax: 1.0, tol: 1e-4 };
    // a C-grid wide enough to engage run_grid's early-freeze machinery
    let cs: Vec<f64> = (0..12).map(|i| 0.05 * 2.0f64.powi(i)).collect();

    for t in THREAD_COUNTS {
        obs::trace::disable();
        let ulv = UlvFactor::new_threaded(&compressed.hss, beta, t).unwrap();
        let base = AdmmSolver::new(&ulv, &compressed.pds.y, ap).with_threads(t).run_grid(&cs);

        let buf = SharedBuf::default();
        obs::trace::init_writer(Box::new(buf.clone()));
        let traced = AdmmSolver::new(&ulv, &compressed.pds.y, ap).with_threads(t).run_grid(&cs);
        obs::trace::disable();

        assert_eq!(base.len(), traced.len());
        for (j, (a, b)) in base.iter().zip(traced.iter()).enumerate() {
            let label = format!("threads={t} C={}", cs[j]);
            assert_eq!(a.z, b.z, "{label}: z differs");
            assert_eq!(a.x, b.x, "{label}: x differs");
            assert_eq!(a.mu, b.mu, "{label}: mu differs");
            assert_eq!(a.primal, b.primal, "{label}: primal curve differs");
            assert_eq!(a.dual, b.dual, "{label}: dual curve differs");
        }

        // schema check + one admm_done per column
        let text = buf.text();
        let events: Vec<TraceEvent> = text
            .lines()
            .map(|l| TraceEvent::from_json(l).unwrap_or_else(|e| panic!("bad line {l:?}: {e}")))
            .collect();
        let done = events.iter().filter(|e| matches!(e, TraceEvent::AdmmDone { .. })).count();
        assert_eq!(done, cs.len(), "threads={t}: one admm_done per C column");
    }
}

#[test]
fn every_emitted_event_round_trips_through_the_validator() {
    // Schema round-trip over the full exemplar set — the same validator
    // the CI obs-smoke job runs against a real traced run.
    for ev in TraceEvent::exemplars() {
        let line = ev.to_json();
        let back = TraceEvent::from_json(&line)
            .unwrap_or_else(|e| panic!("{line} failed to parse: {e}"));
        assert_eq!(back, ev, "round-trip mismatch for {line}");
    }
}
