//! End-to-end integration across modules: data → cluster → HSS → ULV →
//! ADMM → model → prediction, plus cross-solver agreement.

use hss_svm::admm::AdmmParams;
use hss_svm::baselines::{smo::SmoParams, train_racqp, train_smo, RacqpParams};
use hss_svm::data::{scale, synth};
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::{predict, train::train_hss_svm, HssSvmTrainer};
use hss_svm::util::prng::Rng;

#[test]
fn checkerboard_needs_nonlinearity_and_gets_it() {
    // linear kernel fails on a checkerboard, Gaussian succeeds — the
    // "nonlinear SVMs produce significantly higher quality" premise.
    let mut rng = Rng::new(201);
    let train = synth::checkerboard(1200, 3, &mut rng);
    let test = synth::checkerboard(600, 3, &mut rng);
    let admm = AdmmParams { beta: 10.0, max_it: 20, relax: 1.0, tol: 0.0 };
    let mut hp = HssParams::near_exact();
    hp.leaf_size = 96;

    let (gauss_model, _) =
        train_hss_svm(&train, Kernel::Gaussian { h: 0.15 }, &hp, &admm, 10.0, 2).unwrap();
    let gauss_acc = predict::accuracy(&gauss_model, &test, 2);
    assert!(gauss_acc > 0.9, "gaussian checkerboard accuracy {gauss_acc}");

    let (lin_model, _) = train_smo(&train, Kernel::Linear, 1.0, &SmoParams {
        max_iter: 20_000,
        ..Default::default()
    });
    let lin_acc = predict::accuracy(&lin_model, &test, 2);
    assert!(lin_acc < 0.7, "linear kernel should fail on checkerboard: {lin_acc}");
}

#[test]
fn three_solvers_agree_on_scaled_table1_miniature() {
    // miniature ijcnn1-like workload through the full preprocessing path
    let spec = synth::table1_spec("ijcnn1").unwrap();
    let (mut train, mut test) = spec.generate(0.01, 42); // ~500 points
    scale::scale_pair(&mut train, &mut test);
    let kernel = Kernel::Gaussian { h: 1.0 };
    let c = 1.0;

    let mut hp = HssParams::high_accuracy();
    hp.leaf_size = 64;
    let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
    let (hss_model, stats) = train_hss_svm(&train, kernel, &hp, &admm, c, 2).unwrap();
    let hss_acc = predict::accuracy(&hss_model, &test, 2);

    let (smo_model, _) = train_smo(&train, kernel, c, &Default::default());
    let smo_acc = predict::accuracy(&smo_model, &test, 2);

    let (racqp_model, _) = train_racqp(
        &train,
        kernel,
        c,
        &RacqpParams { block_size: 100, beta: 1.0, sweeps: 25, seed: 5 },
    )
    .unwrap();
    let racqp_acc = predict::accuracy(&racqp_model, &test, 2);

    // the paper's Table 4/5-vs-2/3 claim: comparable accuracy. The paper
    // itself reports a ~3.6pt gap on ijcnn1 (92.40 HSS-ADMM vs 96.01
    // LIBSVM) — "comparable" means within a few points, not equal.
    assert!(hss_acc > 0.75, "hss accuracy {hss_acc}");
    assert!(smo_acc - hss_acc < 0.12, "hss {hss_acc} vs smo {smo_acc}");
    assert!(racqp_acc - hss_acc < 0.12, "hss {hss_acc} vs racqp {racqp_acc}");
    assert!(stats.admm_secs < stats.compress_secs + stats.factor_secs + 1.0);
}

#[test]
fn grid_search_reuse_is_cheaper_than_recompression() {
    use std::time::Instant;
    let mut rng = Rng::new(202);
    let train = synth::blobs(1500, 8, 5, 0.3, &mut rng);
    let kernel = Kernel::Gaussian { h: 1.0 };
    let mut hp = HssParams::low_accuracy();
    hp.leaf_size = 128;

    let t0 = Instant::now();
    let trainer = HssSvmTrainer::compress(&train, kernel, &hp, 2);
    let ulv = trainer.factor(100.0).unwrap();
    let setup = t0.elapsed().as_secs_f64();

    let admm = AdmmParams { beta: 100.0, max_it: 10, relax: 1.0, tol: 0.0 };
    let solver = hss_svm::admm::AdmmSolver::new(&ulv, &trainer.y, admm);
    let t1 = Instant::now();
    for c in [0.1, 1.0, 10.0] {
        let (_model, out) = trainer.train_c_with_solver(&solver, c);
        assert_eq!(out.z.len(), train.len());
    }
    let grid = t1.elapsed().as_secs_f64();
    // ADMM-per-C must be much cheaper than compression+factorization
    // (paper: "ADMM Time is completely negligible")
    assert!(
        grid < setup * 0.8,
        "grid over 3 C values ({grid:.3}s) should be well under setup ({setup:.3}s)"
    );
}

#[test]
fn labels_and_permutations_survive_the_pipeline() {
    let mut rng = Rng::new(203);
    let train = synth::two_moons(257, 0.07, &mut rng); // odd size
    let kernel = Kernel::Gaussian { h: 0.35 };
    let trainer = HssSvmTrainer::compress(&train, kernel, &HssParams::near_exact(), 1);
    // permuted labels must be a permutation of the originals
    let mut a: Vec<i64> = train.y.iter().map(|&v| v as i64).collect();
    let mut b: Vec<i64> = trainer.y.iter().map(|&v| v as i64).collect();
    a.sort_unstable();
    b.sort_unstable();
    assert_eq!(a, b);
    // training still works on odd sizes
    let ulv = trainer.factor(10.0).unwrap();
    let (model, _) = trainer.train_c(&ulv, &AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 }, 5.0);
    let acc = predict::accuracy(&model, &train, 1);
    assert!(acc > 0.95, "train accuracy {acc}");
}
