//! End-to-end contracts of the multilevel trainer (ISSUE 10 tentpole;
//! DESIGN.md §15):
//!
//! * **Thread invariance** — context build + coarse-to-fine training are
//!   bit-for-bit identical at 1, 2 and 8 threads (models AND the level
//!   schedule), extending the `tests/thread_invariance.rs` contract one
//!   layer up.
//! * **SV inheritance** — the support vectors of level ℓ are a subset of
//!   level ℓ+1's training set (`SV_ℓ ⊆ T_{ℓ+1}`), the monotonicity the
//!   warm start relies on.
//! * **Edge coarse levels** — `--coarse-level 0` (a single-node frontier)
//!   and an out-of-range level both degrade gracefully and still train.
//! * **Persistence** — a multilevel-trained model is an ordinary binary
//!   model: save/load roundtrips bitwise and predicts identically.

use hss_svm::admm::AdmmParams;
use hss_svm::data::synth;
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::multilevel::{LevelStats, MultilevelContext, MultilevelParams};
use hss_svm::svm::{persist, predict, SvmModel};
use hss_svm::util::prng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

fn fixture() -> (hss_svm::data::Dataset, HssParams, AdmmParams) {
    let mut rng = Rng::new(10_007);
    let ds = synth::xor_blobs(900, 4, 0.35, &mut rng);
    let mut hp = HssParams::low_accuracy();
    hp.leaf_size = 48;
    let admm = AdmmParams { beta: 100.0, max_it: 8, relax: 1.0, tol: 0.0 };
    (ds, hp, admm)
}

fn assert_models_bitwise(a: &SvmModel, b: &SvmModel, label: &str) {
    assert!(a.sv == b.sv, "{label}: SV coordinates differ bitwise");
    assert_eq!(a.alpha_y, b.alpha_y, "{label}: alpha_y differs bitwise");
    assert_eq!(a.bias.to_bits(), b.bias.to_bits(), "{label}: bias differs bitwise");
    assert_eq!(a.labels, b.labels, "{label}: label pair differs");
}

fn assert_schedules_equal(a: &[LevelStats], b: &[LevelStats], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: level count differs");
    for (la, lb) in a.iter().zip(b.iter()) {
        assert_eq!(la.level, lb.level, "{label}: level id differs");
        assert_eq!(la.t_idx, lb.t_idx, "{label}: training set differs at level {}", la.level);
        assert_eq!(la.sv_idx, lb.sv_idx, "{label}: SV set differs at level {}", la.level);
        assert_eq!(la.full_fallback, lb.full_fallback, "{label}: fallback flag differs");
    }
}

#[test]
fn multilevel_models_bitwise_across_thread_counts() {
    let (ds, hp, admm) = fixture();
    let kernel = Kernel::Gaussian { h: 1.2 };
    let ml = MultilevelParams { screen_eps: 0.15, ..Default::default() };
    let cs = [0.5, 1.0, 4.0];

    let base_ctx = MultilevelContext::new(&ds, &hp, &ml, 1);
    let base = base_ctx.train_grid(kernel, &admm, &cs).unwrap();
    assert_eq!(base.results.len(), cs.len());
    for t in THREAD_COUNTS {
        let ctx = MultilevelContext::new(&ds, &hp, &ml, t);
        assert_eq!(ctx.pool_sizes(), base_ctx.pool_sizes(), "schedule differs at threads={t}");
        assert_eq!(ctx.kept(), base_ctx.kept(), "screening differs at threads={t}");
        let run = ctx.train_grid(kernel, &admm, &cs).unwrap();
        assert_schedules_equal(&run.levels, &base.levels, &format!("threads={t}"));
        for (j, ((m, out), (bm, bout))) in
            run.results.iter().zip(base.results.iter()).enumerate()
        {
            let label = format!("threads={t} C={}", cs[j]);
            assert_models_bitwise(m, bm, &label);
            assert_eq!(out.z, bout.z, "{label}: final z differs bitwise");
            assert_eq!(out.mu, bout.mu, "{label}: final mu differs bitwise");
        }
    }
}

#[test]
fn sv_inheritance_is_monotone() {
    let (ds, hp, admm) = fixture();
    let ctx = MultilevelContext::new(&ds, &hp, &MultilevelParams::default(), 2);
    let run = ctx.train_grid(Kernel::Gaussian { h: 1.2 }, &admm, &[0.5, 2.0]).unwrap();
    assert!(run.levels.len() >= 2, "fixture should schedule at least two levels");
    for w in run.levels.windows(2) {
        let (prev, next) = (&w[0], &w[1]);
        // both index lists are sorted pds positions — subset by merge scan
        let mut it = next.t_idx.iter().peekable();
        for &sv in &prev.sv_idx {
            while it.peek().is_some_and(|&&p| p < sv) {
                it.next();
            }
            assert_eq!(
                it.peek().copied().copied(),
                Some(sv),
                "SV {sv} of level {} missing from level {}'s training set",
                prev.level,
                next.level
            );
        }
        assert!(next.n_points >= prev.n_sv, "level {} lost inherited SVs", next.level);
    }
}

#[test]
fn edge_coarse_levels_still_train() {
    let (ds, hp, admm) = fixture();
    let kernel = Kernel::Gaussian { h: 1.2 };
    let (train, test) = ds.split_at(700);
    // L = 0: the root frontier is one node → one representative, below
    // min_level_points, so the schedule degrades to deeper levels.
    // L = usize::MAX: clamped to the deepest level.
    for coarse in [Some(0), Some(usize::MAX)] {
        let ml = MultilevelParams { coarse_level: coarse, ..Default::default() };
        let ctx = MultilevelContext::new(&train, &hp, &ml, 2);
        let (model, out, levels) = ctx.train(kernel, &admm, 1.0).unwrap();
        assert!(model.n_sv() > 0, "coarse={coarse:?}: empty model");
        assert!(out.iterations() > 0, "coarse={coarse:?}: ADMM never ran");
        assert!(!levels.is_empty(), "coarse={coarse:?}: empty schedule");
        let final_level = levels.last().unwrap();
        assert_eq!(final_level.level, usize::MAX, "last level must be the full-resolution one");
        let acc = predict::accuracy(&model, &test, 2);
        assert!(acc > 0.9, "coarse={coarse:?}: accuracy collapsed to {acc}");
    }
}

#[test]
fn multilevel_model_persists_and_roundtrips() {
    let (ds, hp, admm) = fixture();
    let ctx = MultilevelContext::new(&ds, &hp, &MultilevelParams::default(), 2);
    let (model, _, _) = ctx.train(Kernel::Gaussian { h: 1.2 }, &admm, 1.0).unwrap();
    let path = std::env::temp_dir().join(format!("hss_multilevel_{}.model", std::process::id()));
    persist::save(&model, &path).unwrap();
    let loaded = persist::load(&path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_models_bitwise(&model, &loaded, "persist roundtrip");
    let f0 = predict::decision_function(&model, &ds.x, 1);
    let f1 = predict::decision_function(&loaded, &ds.x, 1);
    assert_eq!(f0, f1, "loaded model predicts differently");
}
