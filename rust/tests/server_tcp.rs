//! TCP server integration tests over real sockets: N concurrent
//! connections must get in-order, offline-bitwise-identical
//! predictions; a malformed line must fail only its issuer's lines in
//! the shared tile; MODEL/RELOAD hot swaps must never mix models within
//! a connection's pre/post-command windows; the mtime poll must pick up
//! overwritten model files; backpressure must answer (not drop or
//! block) overflow lines; and shutdown under load must drain cleanly.

use hss_svm::data::{libsvm, DEFAULT_LABEL_PAIR};
use hss_svm::kernel::Kernel;
use hss_svm::linalg::Mat;
use hss_svm::serve;
use hss_svm::server::{ModelRegistry, Server, ServerConfig, ServerHandle};
use hss_svm::svm::{persist, predict, SvmModel};
use hss_svm::util::prng::Rng;
use std::io::{BufRead, BufReader, Cursor, Write};
use std::net::TcpStream;
use std::time::Duration;

const DIM: usize = 6; // < 32 so Repr::Auto stays dense on every path

fn toy_model(seed: u64, n_sv: usize, bias_shift: f64) -> SvmModel {
    let mut rng = Rng::new(seed);
    SvmModel {
        sv: Mat::gauss(n_sv, DIM, &mut rng).into(),
        alpha_y: (0..n_sv).map(|_| rng.gauss()).collect(),
        bias: rng.gauss() + bias_shift,
        kernel: Kernel::Gaussian { h: 0.8 },
        c: 1.0,
        labels: DEFAULT_LABEL_PAIR,
    }
}

fn feature_line(rng: &mut Rng) -> String {
    let a = 1 + rng.below(DIM / 2);
    let b = a + 1 + rng.below(DIM - a);
    format!("{a}:{:.3} {b}:{:.3}", rng.gauss(), rng.gauss())
}

/// What `cmd_predict` would answer for these exact lines: label-agnostic
/// parse, native decision function, label-mapped formatting.
fn offline(model: &SvmModel, lines: &[String]) -> Vec<String> {
    let (x, _) =
        libsvm::read_features(Cursor::new(lines.join("\n")), Some(model.sv.cols())).unwrap();
    predict::decision_function(model, &x, 1)
        .into_iter()
        .map(|v| serve::format_prediction(model, v))
        .collect()
}

fn start(
    registry: ModelRegistry,
    cfg: ServerConfig,
) -> (ServerHandle, std::thread::JoinHandle<anyhow::Result<()>>) {
    let server = Server::bind("127.0.0.1:0", registry, cfg).expect("bind");
    let handle = server.handle();
    let jh = std::thread::spawn(move || server.run());
    (handle, jh)
}

fn connect(handle: &ServerHandle) -> (BufReader<TcpStream>, TcpStream) {
    let s = TcpStream::connect(handle.local_addr()).expect("connect");
    (BufReader::new(s.try_clone().expect("clone")), s)
}

fn send_line(w: &mut TcpStream, line: &str) {
    writeln!(w, "{line}").expect("send");
}

fn read_line(r: &mut BufReader<TcpStream>) -> String {
    let mut s = String::new();
    let n = r.read_line(&mut s).expect("read");
    assert!(n > 0, "unexpected EOF");
    s.trim_end().to_string()
}

#[test]
fn concurrent_connections_get_in_order_offline_identical_predictions() {
    let model = toy_model(50, 9, 0.0);
    let cfg = ServerConfig {
        threads: 2,
        batch_wait: Duration::from_millis(2),
        ..Default::default()
    };
    let (handle, server) = start(ModelRegistry::single(model.clone()), cfg);

    const CONNS: usize = 8;
    const LINES: usize = 120;
    std::thread::scope(|s| {
        for c in 0..CONNS {
            let model = &model;
            let handle = &handle;
            s.spawn(move || {
                let mut rng = Rng::new(100 + c as u64);
                let lines: Vec<String> = (0..LINES).map(|_| feature_line(&mut rng)).collect();
                let want = offline(model, &lines);
                let (mut r, mut w) = connect(handle);
                for l in &lines {
                    send_line(&mut w, l);
                }
                for (i, want_line) in want.iter().enumerate() {
                    let got = read_line(&mut r);
                    assert_eq!(
                        &got, want_line,
                        "conn {c} line {i}: server differs from offline predict"
                    );
                }
            });
        }
    });
    handle.shutdown();
    server.join().unwrap().unwrap();
}

#[test]
fn malformed_line_fails_only_its_issuers_lines() {
    let model = toy_model(51, 7, 0.0);
    // long batch wait so both connections' lines share one tile
    let cfg = ServerConfig {
        threads: 2,
        batch_wait: Duration::from_millis(60),
        ..Default::default()
    };
    let (handle, server) = start(ModelRegistry::single(model.clone()), cfg);

    const LINES: usize = 50;
    const BAD_AT: usize = 24; // 0-based index of the malformed line
    std::thread::scope(|s| {
        // connection A: one malformed line in the middle
        let ha = &handle;
        let ma = &model;
        s.spawn(move || {
            let mut rng = Rng::new(200);
            let mut lines: Vec<String> = (0..LINES).map(|_| feature_line(&mut rng)).collect();
            lines[BAD_AT] = "+1 2:1 2:2".to_string(); // duplicate index
            let want = {
                let mut good = lines.clone();
                good.remove(BAD_AT);
                offline(ma, &good)
            };
            let (mut r, mut w) = connect(ha);
            for l in &lines {
                send_line(&mut w, l);
            }
            let mut good_i = 0usize;
            for i in 0..LINES {
                let got = read_line(&mut r);
                if i == BAD_AT {
                    assert!(
                        got.starts_with("ERR") && got.contains(&format!("line {}", BAD_AT + 1)),
                        "bad line answer: {got}"
                    );
                    continue;
                }
                if got.starts_with("ERR") {
                    // collateral of sharing a tile with the bad line
                    assert!(got.contains("dropped"), "{got}");
                } else {
                    // in-order: a served line must match ITS offline value
                    assert_eq!(got, want[good_i], "conn A line {i}");
                }
                good_i += 1;
            }
        });
        // connection B: all good lines, all must be served bitwise
        let hb = &handle;
        let mb = &model;
        s.spawn(move || {
            let mut rng = Rng::new(201);
            let lines: Vec<String> = (0..LINES).map(|_| feature_line(&mut rng)).collect();
            let want = offline(mb, &lines);
            let (mut r, mut w) = connect(hb);
            for l in &lines {
                send_line(&mut w, l);
            }
            for (i, want_line) in want.iter().enumerate() {
                let got = read_line(&mut r);
                assert_eq!(&got, want_line, "conn B line {i} must be unaffected");
            }
        });
    });
    handle.shutdown();
    server.join().unwrap().unwrap();
}

#[test]
fn model_command_reload_and_hot_swap_never_mix_within_a_window() {
    let dir = std::env::temp_dir().join(format!("hss_svm_server_swap_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let pa = dir.join("a.model");
    let pb = dir.join("b.model");
    let model_a = toy_model(60, 6, 50.0); // biases far apart: decisions
    let model_b = toy_model(61, 8, -50.0); // are unambiguously attributable
    persist::save(&model_a, &pa).unwrap();
    persist::save(&model_b, &pb).unwrap();

    let registry = ModelRegistry::from_paths(&[
        ("default".to_string(), pa.clone()),
        ("alt".to_string(), pb.clone()),
    ])
    .unwrap();
    let (handle, server) = start(registry, ServerConfig { threads: 2, ..Default::default() });

    let mut rng = Rng::new(300);
    let lines: Vec<String> = (0..40).map(|_| feature_line(&mut rng)).collect();
    let want_a = offline(&model_a, &lines);
    let want_b = offline(&model_b, &lines);

    let (mut r, mut w) = connect(&handle);
    // window 1: default model, every line answers as A — bitwise
    for l in &lines {
        send_line(&mut w, l);
    }
    for want in &want_a {
        assert_eq!(&read_line(&mut r), want);
    }
    // switch to "alt": responses flip to B, never a mix
    send_line(&mut w, "MODEL alt");
    assert_eq!(read_line(&mut r), "OK model alt gen 1");
    for l in &lines {
        send_line(&mut w, l);
    }
    for want in &want_b {
        assert_eq!(&read_line(&mut r), want);
    }
    send_line(&mut w, "MODEL nope");
    assert!(read_line(&mut r).starts_with("ERR unknown model"));

    // hot swap: overwrite a.model (different SV count => different
    // size) and RELOAD; in-flight window stays A, next window is the
    // new model — bitwise, with no blending inside either window
    let model_c = toy_model(62, 10, 200.0);
    persist::save(&model_c, &pa).unwrap();
    let want_c = offline(&model_c, &lines);
    send_line(&mut w, "MODEL default");
    assert_eq!(read_line(&mut r), "OK model default gen 1");
    send_line(&mut w, "RELOAD default");
    assert_eq!(read_line(&mut r), "OK reloaded default gen 2");
    // the MODEL command snapshot is per-request, so post-RELOAD lines
    // pick up generation 2 immediately
    for l in &lines {
        send_line(&mut w, l);
    }
    for want in &want_c {
        assert_eq!(&read_line(&mut r), want);
    }

    send_line(&mut w, "QUIT");
    assert_eq!(read_line(&mut r), "OK bye");
    handle.shutdown();
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn changed_mtime_is_picked_up_without_reload_command() {
    let dir = std::env::temp_dir().join(format!("hss_svm_server_mtime_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("m.model");
    let model_a = toy_model(70, 5, 100.0);
    persist::save(&model_a, &p).unwrap();

    let registry = ModelRegistry::from_paths(&[("default".to_string(), p.clone())]).unwrap();
    let cfg = ServerConfig {
        threads: 1,
        poll_interval: Duration::from_millis(20),
        ..Default::default()
    };
    let (handle, server) = start(registry, cfg);

    let mut rng = Rng::new(301);
    let probe = feature_line(&mut rng);
    let probe_a = offline(&model_a, std::slice::from_ref(&probe));
    let (mut r, mut w) = connect(&handle);
    send_line(&mut w, &probe);
    assert_eq!(read_line(&mut r), probe_a[0]);

    // overwrite the file (different SV count => size change guarantees
    // a staleness hit even with coarse mtimes) and wait for the poll
    let model_b = toy_model(71, 9, -100.0);
    persist::save(&model_b, &p).unwrap();
    let probe_b = offline(&model_b, std::slice::from_ref(&probe));
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        std::thread::sleep(Duration::from_millis(30));
        send_line(&mut w, &probe);
        let got = read_line(&mut r);
        if got == probe_b[0] {
            break; // hot-swapped
        }
        assert_eq!(got, probe_a[0], "must be exactly old or new, never a blend");
        assert!(std::time::Instant::now() < deadline, "mtime poll never swapped");
    }
    handle.shutdown();
    server.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn overload_answers_with_backpressure_errors_not_hangs() {
    let model = toy_model(80, 6, 0.0);
    let cfg = ServerConfig {
        threads: 1,
        max_inflight: 4,
        batch_wait: Duration::from_millis(250),
        ..Default::default()
    };
    let (handle, server) = start(ModelRegistry::single(model.clone()), cfg);

    let mut rng = Rng::new(400);
    let lines: Vec<String> = (0..120).map(|_| feature_line(&mut rng)).collect();
    let want = offline(&model, &lines);
    let (mut r, mut w) = connect(&handle);
    for l in &lines {
        send_line(&mut w, l);
    }
    let (mut served, mut rejected) = (0usize, 0usize);
    for i in 0..lines.len() {
        let got = read_line(&mut r);
        if got.starts_with("ERR") {
            assert!(
                got.contains("overloaded") && got.contains(&format!("line {}", i + 1)),
                "{got}"
            );
            rejected += 1;
        } else {
            // responses stay in order and bitwise-correct under pressure
            assert_eq!(got, want[i], "line {i}");
            served += 1;
        }
    }
    assert_eq!(served + rejected, lines.len());
    assert!(rejected > 0, "queue of 4 cannot absorb 120 instant lines");
    assert!(served >= 4, "queued lines must still be answered");
    handle.shutdown();
    server.join().unwrap().unwrap();
}

#[test]
fn metrics_exposition_is_parseable_under_load() {
    let model = toy_model(85, 6, 0.0);
    let cfg = ServerConfig {
        threads: 2,
        batch_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let (handle, server) = start(ModelRegistry::single(model.clone()), cfg);

    // some traffic first, so counters and the latency histogram are
    // non-trivial
    let mut rng = Rng::new(450);
    let lines: Vec<String> = (0..60).map(|_| feature_line(&mut rng)).collect();
    let want = offline(&model, &lines);
    let (mut r, mut w) = connect(&handle);
    for l in &lines {
        send_line(&mut w, l);
    }
    for (i, want_line) in want.iter().enumerate() {
        assert_eq!(&read_line(&mut r), want_line, "line {i}");
    }

    // METRICS: a multi-line Prometheus exposition, read until "# EOF"
    send_line(&mut w, "METRICS");
    let mut body = Vec::new();
    loop {
        let line = read_line(&mut r);
        let done = line == "# EOF";
        body.push(line);
        if done {
            break;
        }
    }
    let text = body.join("\n");
    for needle in [
        "# TYPE hss_svm_connections_total counter",
        "# TYPE hss_svm_queue_depth gauge",
        "# TYPE hss_svm_request_latency_seconds histogram",
        "hss_svm_predictions_total 60",
        "hss_svm_request_latency_seconds_count 60",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
    }
    // every sample line is "name[{labels}] value" with a float value,
    // and the histogram buckets are cumulative up to +Inf == count
    let mut cums: Vec<f64> = Vec::new();
    for line in body.iter().filter(|l| !l.starts_with('#')) {
        let val = line.rsplit(' ').next().unwrap();
        let v: f64 = val.parse().unwrap_or_else(|_| panic!("bad sample value in {line:?}"));
        if line.starts_with("hss_svm_request_latency_seconds_bucket") {
            cums.push(v);
        }
    }
    assert!(cums.len() >= 2, "expected bucket lines:\n{text}");
    assert!(cums.windows(2).all(|p| p[0] <= p[1]), "non-cumulative buckets: {cums:?}");
    assert_eq!(*cums.last().unwrap(), 60.0, "+Inf bucket == count");

    // the connection still serves predictions after the multi-line
    // response — framing intact
    let probe = feature_line(&mut rng);
    let probe_want = offline(&model, std::slice::from_ref(&probe));
    send_line(&mut w, &probe);
    assert_eq!(read_line(&mut r), probe_want[0]);

    handle.shutdown();
    server.join().unwrap().unwrap();
}

#[test]
fn stats_report_and_clean_shutdown_under_load() {
    let model = toy_model(90, 7, 0.0);
    let cfg = ServerConfig {
        threads: 2,
        batch_wait: Duration::from_millis(1),
        ..Default::default()
    };
    let (handle, server) = start(ModelRegistry::single(model.clone()), cfg);

    // lock-step load clients: serve until the server goes away
    let load = |seed: u64, handle: ServerHandle, model: SvmModel| {
        std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            let (mut r, mut w) = connect(&handle);
            let mut ok = 0usize;
            loop {
                let line = feature_line(&mut rng);
                if writeln!(w, "{line}").is_err() {
                    break;
                }
                let mut resp = String::new();
                match r.read_line(&mut resp) {
                    Ok(n) if n > 0 => {
                        let want = offline(&model, std::slice::from_ref(&line));
                        assert_eq!(resp.trim_end(), want[0]);
                        ok += 1;
                    }
                    _ => break, // server drained and closed
                }
            }
            ok
        })
    };
    let clients: Vec<_> = (0..4).map(|i| load(500 + i, handle.clone(), model.clone())).collect();
    std::thread::sleep(Duration::from_millis(150));

    // a control connection inspects STATS and then shuts the server down
    let (mut r, mut w) = connect(&handle);
    send_line(&mut w, "# comment lines are skipped, not answered");
    send_line(&mut w, "STATS");
    let stats = read_line(&mut r);
    assert!(stats.starts_with("OK stats "), "{stats}");
    for key in ["connections=", "predicted=", "p50_us=", "p99_us=", "queue="] {
        assert!(stats.contains(key), "{stats} missing {key}");
    }
    send_line(&mut w, "SHUTDOWN");
    assert_eq!(read_line(&mut r), "OK shutting down");

    server.join().unwrap().expect("clean shutdown under load");
    let mut total = 0usize;
    for c in clients {
        total += c.join().unwrap();
    }
    assert!(total > 0, "load clients must have been served before shutdown");
    let summary = handle.summary();
    assert!(summary.contains("predictions"), "{summary}");
}
