//! Integration: PJRT-executed artifacts vs the native Rust kernel path.
//!
//! Requires `make artifacts` (skips cleanly when absent so `cargo test`
//! works before the Python step, but the Makefile always builds them).

use hss_svm::data::{synth, Points};
use hss_svm::kernel::{kernel_block, Kernel};
use hss_svm::linalg::Mat;
use hss_svm::runtime::{decision_function_pjrt, predict_pjrt, PjrtRuntime};
use hss_svm::svm::{predict, SvmModel};
use hss_svm::util::prng::Rng;

fn runtime() -> Option<PjrtRuntime> {
    let rt = PjrtRuntime::try_default();
    if rt.is_none() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts` first");
    }
    rt
}

#[test]
fn kernel_tile_matches_native() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(1);
    for &(m, n, f) in &[(128usize, 128usize, 8usize), (128, 128, 122), (64, 100, 8), (1, 1, 3)] {
        let x = Mat::gauss(m, f, &mut rng);
        let y = Mat::gauss(n, f, &mut rng);
        for h in [0.3, 1.0, 4.0] {
            let k = Kernel::Gaussian { h };
            let native = kernel_block(&k, &x, &y);
            let pjrt = rt.kernel_tile(&x, &y, k.gamma()).unwrap();
            assert_eq!(pjrt.shape(), (m, n));
            for i in 0..m {
                for j in 0..n {
                    let (a, b) = (native[(i, j)], pjrt[(i, j)]);
                    assert!(
                        (a - b).abs() < 5e-5,
                        "tile mismatch at ({i},{j}) f={f} h={h}: {a} vs {b}"
                    );
                }
            }
        }
    }
}

#[test]
fn decision_tile_matches_native_model() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(2);
    // SV count crossing the 1024 chunk boundary exercises accumulation
    for &(t, s, f) in &[(128usize, 1024usize, 8usize), (77, 1500, 22), (128, 100, 122)] {
        let model = SvmModel {
            sv: Mat::gauss(s, f, &mut rng).into(),
            alpha_y: (0..s).map(|_| rng.gauss()).collect(),
            bias: rng.gauss(),
            kernel: Kernel::Gaussian { h: 1.0 },
            c: 1.0,
            labels: hss_svm::data::DEFAULT_LABEL_PAIR,
        };
        let x = Points::Dense(Mat::gauss(t, f, &mut rng));
        let native = predict::decision_function(&model, &x, 1);
        let pj = decision_function_pjrt(&rt, &model, &x).unwrap();
        assert_eq!(pj.len(), t);
        for i in 0..t {
            // f32 accumulation over up to 1500 SVs: tolerance scales
            let tol = 5e-4 * (1.0 + native[i].abs());
            assert!(
                (native[i] - pj[i]).abs() < tol,
                "decision mismatch at {i} (t={t},s={s},f={f}): {} vs {}",
                native[i],
                pj[i]
            );
        }
    }
}

#[test]
fn end_to_end_predictions_agree() {
    let Some(rt) = runtime() else { return };
    let mut rng = Rng::new(3);
    let train = synth::two_moons(300, 0.08, &mut rng);
    let test = synth::two_moons(200, 0.08, &mut rng);
    let (model, _) = hss_svm::svm::train::train_hss_svm(
        &train,
        Kernel::Gaussian { h: 0.3 },
        &hss_svm::hss::HssParams::near_exact(),
        &hss_svm::admm::AdmmParams { beta: 10.0, max_it: 20, relax: 1.0, tol: 0.0 },
        10.0,
        2,
    )
    .unwrap();
    let native = predict::predict(&model, &test.x, 2);
    let pj = predict_pjrt(&rt, &model, &test.x).unwrap();
    let agree = native.iter().zip(pj.iter()).filter(|(a, b)| a == b).count();
    // f32 vs f64 can flip points sitting exactly on the boundary
    assert!(agree + 2 >= test.len(), "only {agree}/{} labels agree", test.len());
    let (k_calls, d_calls) = (
        rt.stats.kernel_tile_calls.load(std::sync::atomic::Ordering::Relaxed),
        rt.stats.decision_tile_calls.load(std::sync::atomic::Ordering::Relaxed),
    );
    assert!(d_calls > 0, "PJRT was not actually used ({k_calls}, {d_calls})");
}
