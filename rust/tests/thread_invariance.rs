//! Thread-invariance contract (ISSUE 2 tentpole): every level-scheduled
//! tree traversal — compression, ULV factorization, the blocked solves
//! and the matvec, plus the batched ADMM C-grid on top of them — must be
//! **bit-for-bit identical** for every thread count. Levels are barriers
//! and per-node arithmetic is shared with the serial path, so nothing may
//! drift, not even in the last ulp. Ragged trees (non-power-of-two leaf
//! counts from 2-means splits) and the single-leaf degenerate tree are
//! exercised explicitly.

use hss_svm::admm::{AdmmOutput, AdmmParams, AdmmSolver};
use hss_svm::data::synth;
use hss_svm::hss::compress::{compress, Compressed};
use hss_svm::hss::matvec;
use hss_svm::hss::ulv::UlvFactor;
use hss_svm::hss::{Hss, HssParams};
use hss_svm::kernel::Kernel;
use hss_svm::linalg::Mat;
use hss_svm::util::prng::Rng;

const THREAD_COUNTS: [usize; 3] = [1, 2, 8];

/// Ragged-tree workload: 437 points is not a power-of-two multiple of the
/// leaf size, and 2-means splits are data-driven, so leaves end up at
/// several different depths.
fn ragged_compressed(threads: usize) -> Compressed {
    let mut rng = Rng::new(9_001);
    let ds = synth::blobs(437, 3, 4, 0.35, &mut rng);
    let kernel = Kernel::Gaussian { h: 1.2 };
    let mut p = HssParams::low_accuracy();
    p.leaf_size = 48;
    compress(&ds, &kernel, &p, threads)
}

fn assert_mats_equal(a: &Option<Mat>, b: &Option<Mat>, what: &str, node: usize) {
    match (a, b) {
        (None, None) => {}
        (Some(ma), Some(mb)) => {
            assert!(ma == mb, "node {node}: {what} differs bitwise");
        }
        _ => panic!("node {node}: {what} presence differs"),
    }
}

fn assert_hss_equal(a: &Hss, b: &Hss) {
    assert_eq!(a.n, b.n);
    assert_eq!(a.perm, b.perm);
    assert_eq!(a.iperm, b.iperm);
    assert_eq!(a.nodes.len(), b.nodes.len());
    for (i, (na, nb)) in a.nodes.iter().zip(b.nodes.iter()).enumerate() {
        assert_eq!((na.begin, na.end), (nb.begin, nb.end), "node {i} extent");
        assert_eq!((na.left, na.right), (nb.left, nb.right), "node {i} children");
        assert_eq!(na.skel, nb.skel, "node {i} skeleton");
        assert_mats_equal(&na.d, &nb.d, "D", i);
        assert_mats_equal(&na.u, &nb.u, "U", i);
        assert_mats_equal(&na.b, &nb.b, "B", i);
    }
}

#[test]
fn compress_bitwise_across_thread_counts() {
    let base = ragged_compressed(1);
    // sanity: the workload really is ragged (leaves on several levels)
    assert!(base.hss.plan.n_levels() >= 3, "workload should build a multi-level tree");
    for t in THREAD_COUNTS {
        let other = ragged_compressed(t);
        assert_hss_equal(&base.hss, &other.hss);
        assert_eq!(base.stats.max_rank, other.stats.max_rank);
        assert_eq!(base.stats.memory_bytes, other.stats.memory_bytes);
        assert_eq!(base.stats.kernel_evals, other.stats.kernel_evals);
    }
}

#[test]
fn factor_and_solves_bitwise_across_thread_counts() {
    let c = ragged_compressed(2);
    // generous shift: the loose compression need not stay PSD, the
    // paper's β = 100 regime keeps every elimination block regular
    let beta = 100.0;
    let mut rng = Rng::new(77);
    let n = c.hss.n;
    let b1: Vec<f64> = (0..n).map(|_| rng.gauss()).collect();
    // wide enough that n·k crosses solve_mat's parallel-sweep threshold
    // (8k elements) — otherwise every thread count takes the serial path
    // and the test proves nothing
    let bk = Mat::gauss(n, 24, &mut rng);
    assert!(n * 24 >= 8192);

    let ulv_serial = UlvFactor::new(&c.hss, beta).unwrap();
    let x1 = ulv_serial.solve(&b1);
    let xk = ulv_serial.solve_mat(&bk);
    for t in THREAD_COUNTS {
        let ulv_t = UlvFactor::new_threaded(&c.hss, beta, t).unwrap();
        assert_eq!(ulv_t.solve(&b1), x1, "vector solve differs at threads={t}");
        let xk_t = ulv_t.solve_mat(&bk);
        assert!(xk_t == xk, "blocked solve differs at threads={t}");
    }
}

#[test]
fn matvec_bitwise_across_thread_counts() {
    let c = ragged_compressed(2);
    let mut rng = Rng::new(78);
    let x: Vec<f64> = (0..c.hss.n).map(|_| rng.gauss()).collect();
    let serial = matvec::matvec(&c.hss, &x);
    for t in THREAD_COUNTS {
        let par = matvec::matvec_threads(&c.hss, &x, t);
        assert_eq!(par, serial, "matvec differs at threads={t}");
    }
}

fn assert_outputs_bitwise(a: &AdmmOutput, b: &AdmmOutput, label: &str) {
    assert_eq!(a.z, b.z, "{label}: z differs");
    assert_eq!(a.x, b.x, "{label}: x differs");
    assert_eq!(a.mu, b.mu, "{label}: mu differs");
    assert_eq!(a.primal, b.primal, "{label}: primal residuals differ");
    assert_eq!(a.dual, b.dual, "{label}: dual residuals differ");
}

#[test]
fn batched_admm_grid_bitwise_across_thread_counts() {
    let c = ragged_compressed(2);
    let beta = 100.0;
    let ap = AdmmParams { beta, max_it: 8, relax: 1.0, tol: 0.0 };
    // a wide C-grid: n·k must cross run_grid's parallel-update
    // threshold (32k elements) so the threaded per-column path is the
    // one under test, not the serial fallback
    let cs: Vec<f64> = (0..80).map(|i| 0.05 * 1.1f64.powi(i)).collect();
    assert!(c.hss.n * cs.len() >= 32_768);

    let ulv1 = UlvFactor::new(&c.hss, beta).unwrap();
    let base = AdmmSolver::new(&ulv1, &c.pds.y, ap).run_grid(&cs);
    for t in THREAD_COUNTS {
        let ulv_t = UlvFactor::new_threaded(&c.hss, beta, t).unwrap();
        let outs = AdmmSolver::new(&ulv_t, &c.pds.y, ap).with_threads(t).run_grid(&cs);
        assert_eq!(outs.len(), base.len());
        for (j, (got, want)) in outs.iter().zip(base.iter()).enumerate() {
            assert_outputs_bitwise(got, want, &format!("threads={t} C={}", cs[j]));
        }
    }
}

#[test]
fn env_default_thread_count_is_invariant() {
    // The CI determinism matrix runs the suite under HSS_SVM_THREADS=1
    // and =2; this test actually consumes that knob (via
    // default_threads) so the legs genuinely exercise different worker
    // counts against the serial reference.
    let t = hss_svm::util::threadpool::default_threads();
    let base = ragged_compressed(1);
    let other = ragged_compressed(t);
    assert_hss_equal(&base.hss, &other.hss);

    let mut rng = Rng::new(80);
    let x: Vec<f64> = (0..base.hss.n).map(|_| rng.gauss()).collect();
    assert_eq!(matvec::matvec_threads(&base.hss, &x, t), matvec::matvec(&base.hss, &x));

    let beta = 100.0;
    let serial = UlvFactor::new(&base.hss, beta).unwrap();
    let env_par = UlvFactor::new_threaded(&base.hss, beta, t).unwrap();
    assert_eq!(env_par.solve(&x), serial.solve(&x), "env-threaded solve differs (threads={t})");
}

#[test]
fn single_leaf_tree_thread_invariant() {
    // n below the leaf size → the root IS the only (leaf) node; every
    // traversal must degrade gracefully and stay thread-invariant
    let mut rng = Rng::new(79);
    let ds = synth::blobs(40, 2, 2, 0.3, &mut rng);
    let kernel = Kernel::Gaussian { h: 0.8 };
    let mut p = HssParams::near_exact();
    p.leaf_size = 64;

    let base = compress(&ds, &kernel, &p, 1);
    assert_eq!(base.hss.nodes.len(), 1);
    let x: Vec<f64> = (0..40).map(|_| rng.gauss()).collect();
    let mv = matvec::matvec(&base.hss, &x);
    let ulv1 = UlvFactor::new(&base.hss, 2.0).unwrap();
    let sol = ulv1.solve(&x);
    for t in THREAD_COUNTS {
        let other = compress(&ds, &kernel, &p, t);
        assert_hss_equal(&base.hss, &other.hss);
        assert_eq!(matvec::matvec_threads(&base.hss, &x, t), mv);
        let ulv_t = UlvFactor::new_threaded(&base.hss, 2.0, t).unwrap();
        assert_eq!(ulv_t.solve(&x), sol);
    }
}
