//! One-vs-one multiclass end-to-end: train → persist → load → predict
//! round-trips (dense and CSR), a TCP serving session answering the
//! training file's ORIGINAL integer class labels, shared-SV engine vs
//! naive per-pair agreement on the loaded model, and bitwise thread
//! invariance of the parallel pairwise trainer.

use hss_svm::admm::AdmmParams;
use hss_svm::data::sparse::CsrMat;
use hss_svm::data::{synth, Points};
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::server::{ModelRegistry, Server, ServerConfig};
use hss_svm::svm::multiclass::{train_ovo, MulticlassDataset};
use hss_svm::svm::{persist, AnyModel, OvoModel};
use hss_svm::util::prng::Rng;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Duration;

/// 4-class blobs remapped onto non-contiguous "original" labels
/// {2, 5, 7, 11} — the round-trips below must answer these, not 0..3.
const LABELS: [i64; 4] = [2, 5, 7, 11];

fn four_class(n: usize, rng: &mut Rng, sparse: bool) -> MulticlassDataset {
    let base = synth::multiclass_blobs(n, 3, 4, 0.4, rng);
    let labels: Vec<i64> = base.labels.iter().map(|&c| LABELS[c as usize]).collect();
    if sparse {
        MulticlassDataset::new("blobs4-csr", CsrMat::from_dense(base.x.dense()), labels)
    } else {
        MulticlassDataset::new("blobs4", base.x, labels)
    }
}

fn train(ds: &MulticlassDataset, threads: usize) -> OvoModel {
    let (model, _) = train_ovo(
        ds,
        Kernel::Gaussian { h: 1.0 },
        &HssParams::near_exact(),
        &AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 },
        5.0,
        threads,
    )
    .expect("ovo training");
    model
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("hss_svm_mc_e2e_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn train_persist_load_predict_roundtrip_dense_and_csr() {
    for sparse in [false, true] {
        let mut rng = Rng::new(901);
        let tr = four_class(240, &mut rng, sparse);
        let te = four_class(120, &mut rng, sparse);
        let model = train(&tr, 2);
        assert_eq!(model.classes(), &LABELS);
        assert_eq!(model.pairs().len(), 6);
        assert_eq!(model.is_sparse(), sparse);
        let acc = model.accuracy(&te, 2);
        assert!(acc > 0.95, "sparse={sparse}: accuracy {acc}");

        let dir = tmp_dir(if sparse { "csr" } else { "dense" });
        let path = dir.join("m.ovo");
        persist::save_ovo(&model, &path).unwrap();
        let back = persist::load_ovo(&path).unwrap();
        assert_eq!(back.classes(), model.classes());
        assert_eq!(back.is_sparse(), sparse);
        // loaded model predicts IDENTICALLY (bit-exact persistence)
        let f1 = model.decisions(&te.x, 2);
        let f2 = back.decisions(&te.x, 2);
        assert_eq!(f1.data(), f2.data(), "sparse={sparse}");
        assert_eq!(model.predict(&te.x, 2), back.predict(&te.x, 2));
        // and the engine agrees with the naive per-pair oracle ≤ 1e-12
        let naive = back.decisions_naive(&te.x, 2);
        for (a, b) in f2.data().iter().zip(naive.data().iter()) {
            assert!(
                (a - b).abs() <= 1e-12 * (1.0 + b.abs()),
                "sparse={sparse}: engine {a} vs naive {b}"
            );
        }
        assert_eq!(back.predict(&te.x, 2), back.predict_naive(&te.x, 2));
        // answers are original labels, never 0..3 vote indices
        assert!(back.predict(&te.x, 2).iter().all(|c| LABELS.contains(c)));
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn parallel_pairwise_training_is_thread_invariant_e2e() {
    let mut rng = Rng::new(902);
    let tr = four_class(200, &mut rng, false);
    let base = train(&tr, 1);
    for threads in [2, 8] {
        let other = train(&tr, threads);
        assert_eq!(base.classes(), other.classes());
        for ((a1, b1, m1), (a2, b2, m2)) in base.pairs().iter().zip(other.pairs().iter()) {
            assert_eq!((a1, b1), (a2, b2), "pair order at threads={threads}");
            assert_eq!(m1.sv, m2.sv, "SVs differ at threads={threads}");
            assert_eq!(m1.alpha_y, m2.alpha_y, "alphas differ at threads={threads}");
            assert_eq!(
                m1.bias.to_bits(),
                m2.bias.to_bits(),
                "bias differs at threads={threads}"
            );
        }
        // bitwise-equal models ⇒ bitwise-equal decisions
        let x = &tr.x;
        assert_eq!(base.decisions(x, 1).data(), other.decisions(x, threads).data());
    }
}

/// What the engine answers offline for these exact lines — the TCP
/// session must match verbatim (`"<class> <decision sum>"`).
fn offline(model: &OvoModel, lines: &[String]) -> Vec<String> {
    let (x, _) = hss_svm::data::libsvm::read_features(
        std::io::Cursor::new(lines.join("\n")),
        Some(model.dim()),
    )
    .unwrap();
    model
        .engine()
        .predict_with_scores(&x, 1)
        .into_iter()
        .map(|(class, sum)| format!("{class} {sum:.6}"))
        .collect()
}

#[test]
fn tcp_session_serves_original_multiclass_labels() {
    let mut rng = Rng::new(903);
    let tr = four_class(200, &mut rng, false);
    let model = train(&tr, 2);
    let dir = tmp_dir("tcp");
    let path = dir.join("mc.ovo");
    persist::save_ovo(&model, &path).unwrap();

    // registry loads the OvO file through the auto-detecting loader
    let registry = ModelRegistry::from_paths(&[("mc".to_string(), path.clone())]).unwrap();
    let loaded = registry.get("mc").unwrap();
    assert!(matches!(loaded.model, AnyModel::Ovo(_)), "registry must detect OvO files");

    let cfg = ServerConfig {
        batch_wait: Duration::from_millis(1),
        threads: 2,
        ..ServerConfig::default()
    };
    let server = Server::bind("127.0.0.1:0", registry, cfg).expect("bind");
    let handle = server.handle();
    let jh = std::thread::spawn(move || server.run());

    // request lines drawn near all four class centers (mixed labeled /
    // unlabeled, exercising the label-agnostic batch parser)
    let q = synth::multiclass_blobs(40, 3, 4, 0.4, &mut rng);
    let mut lines = Vec::new();
    for i in 0..q.len() {
        let p = q.x.dense_row(i);
        let feats = format!("1:{:.4} 2:{:.4} 3:{:.4}", p[0], p[1], p[2]);
        if i % 3 == 0 {
            lines.push(format!("{} {feats}", LABELS[(i / 3) % 4]));
        } else {
            lines.push(feats);
        }
    }
    let want = offline(&model, &lines);

    let stream = TcpStream::connect(handle.local_addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut w = stream;
    for l in &lines {
        writeln!(w, "{l}").expect("send");
    }
    let mut got = Vec::new();
    for _ in 0..lines.len() {
        let mut s = String::new();
        assert!(reader.read_line(&mut s).expect("read") > 0, "unexpected EOF");
        got.push(s.trim_end().to_string());
    }
    assert_eq!(got, want, "served OvO answers must match the offline engine verbatim");
    // every response leads with one of the ORIGINAL training labels
    for g in &got {
        let class: i64 = g.split_whitespace().next().unwrap().parse().unwrap();
        assert!(LABELS.contains(&class), "served label {class} not in {LABELS:?}");
    }
    writeln!(w, "SHUTDOWN").expect("shutdown");
    let mut s = String::new();
    let _ = reader.read_line(&mut s);
    jh.join().unwrap().unwrap();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn stdin_serve_loop_handles_ovo_models() {
    let mut rng = Rng::new(904);
    let tr = four_class(160, &mut rng, false);
    let model = train(&tr, 1);
    let q = four_class(10, &mut rng, false);
    let mut input = String::new();
    for i in 0..q.len() {
        let p = q.x.dense_row(i);
        input.push_str(&format!("1:{:.4} 2:{:.4} 3:{:.4}\n", p[0], p[1], p[2]));
    }
    let any: AnyModel = model.into();
    let mut out = Vec::new();
    let stats = hss_svm::serve::serve_loop(
        &any,
        None,
        std::io::Cursor::new(input),
        &mut out,
        std::io::sink(),
        1,
    )
    .unwrap();
    assert_eq!(stats.predicted, 10);
    let text = String::from_utf8(out).unwrap();
    assert_eq!(text.lines().count(), 10);
    for l in text.lines() {
        let class: i64 = l.split_whitespace().next().unwrap().parse().unwrap();
        assert!(LABELS.contains(&class), "{l}");
    }
}

#[test]
fn sparse_tcp_tiles_follow_the_model_representation() {
    // a CSR OvO model forces CSR request tiles (serve::parse_batch pins
    // the tile representation to the model); answers still match the
    // offline engine bitwise
    let mut rng = Rng::new(905);
    let tr = four_class(160, &mut rng, true);
    let model = train(&tr, 2);
    assert!(model.is_sparse());
    let dir = tmp_dir("tcp_csr");
    let path = dir.join("mc_sparse.ovo");
    persist::save_ovo(&model, &path).unwrap();
    let registry = ModelRegistry::from_paths(&[("mc".to_string(), path)]).unwrap();
    let server = Server::bind(
        "127.0.0.1:0",
        registry,
        ServerConfig { batch_wait: Duration::from_millis(1), ..ServerConfig::default() },
    )
    .expect("bind");
    let handle = server.handle();
    let jh = std::thread::spawn(move || server.run());

    let q = synth::multiclass_blobs(12, 3, 4, 0.4, &mut rng);
    let mut lines = Vec::new();
    for i in 0..q.len() {
        let p = q.x.dense_row(i);
        lines.push(format!("1:{:.4} 3:{:.4}", p[0], p[2])); // sparse line (no 2:)
    }
    let want = {
        let (x, _) = hss_svm::data::libsvm::read_features_with(
            std::io::Cursor::new(lines.join("\n")),
            Some(model.dim()),
            hss_svm::data::libsvm::Repr::Sparse,
        )
        .unwrap();
        assert!(matches!(x, Points::Sparse(_)));
        model
            .engine()
            .predict_with_scores(&x, 1)
            .into_iter()
            .map(|(class, sum)| format!("{class} {sum:.6}"))
            .collect::<Vec<_>>()
    };
    let stream = TcpStream::connect(handle.local_addr()).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut w = stream;
    for l in &lines {
        writeln!(w, "{l}").expect("send");
    }
    let mut got = Vec::new();
    for _ in 0..lines.len() {
        let mut s = String::new();
        assert!(reader.read_line(&mut s).expect("read") > 0, "unexpected EOF");
        got.push(s.trim_end().to_string());
    }
    assert_eq!(got, want);
    handle.shutdown();
    drop(w);
    jh.join().unwrap().unwrap();
}
