//! Compute-backend oracle suite (DESIGN.md §13).
//!
//! Three contracts, in order of strictness:
//!
//! 1. **CpuBackend is bitwise the pre-refactor path.** Every `*_with`
//!    entry point handed the CPU backend must reproduce its legacy
//!    wrapper exactly — dense and CSR operands, threads ∈ {1, 2, 8} —
//!    including end-to-end HSS compression, so the refactor cannot have
//!    perturbed a single bit of the existing goldens.
//! 2. **SimdF32Backend stays within its documented tolerance**: ≤ 1e-4
//!    relative on decision values vs the f64 oracle, and accuracy
//!    parity on a synthetic grid.
//! 3. **Backend choice never changes the predicted class** on
//!    margin-guarded multiclass fixtures (rows whose pairwise decision
//!    values all clear a margin an f32 perturbation cannot flip).

use hss_svm::admm::AdmmParams;
use hss_svm::compute::{self, ComputeBackend};
use hss_svm::data::sparse::CsrMat;
use hss_svm::data::{synth, Dataset, Points};
use hss_svm::hss::HssParams;
use hss_svm::kernel::Kernel;
use hss_svm::svm::train::train_hss_svm;
use hss_svm::svm::{predict, SvmModel};
use hss_svm::util::prng::Rng;

const THREAD_GRID: [usize; 3] = [1, 2, 8];

fn trained_model(seed: u64) -> (SvmModel, Dataset) {
    let mut rng = Rng::new(seed);
    let train = synth::blobs(240, 4, 3, 0.25, &mut rng);
    let test = synth::blobs(160, 4, 3, 0.25, &mut rng);
    let (model, _) = train_hss_svm(
        &train,
        Kernel::Gaussian { h: 1.2 },
        &HssParams::near_exact(),
        &AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
        5.0,
        2,
    )
    .expect("hss training");
    (model, test)
}

#[test]
fn cpu_backend_decisions_bitwise_dense_and_csr_across_threads() {
    let (model, test) = trained_model(71);
    let dense = test.x.clone();
    let sparse = Points::Sparse(CsrMat::from_dense(dense.dense()));
    let b = compute::cpu();
    for x in [&dense, &sparse] {
        for threads in THREAD_GRID {
            let legacy = predict::decision_function(&model, x, threads);
            let routed = predict::decision_function_with(b, &model, x, threads);
            assert_eq!(legacy, routed, "CpuBackend drifted (threads={threads})");
            assert_eq!(
                predict::predict(&model, x, threads),
                predict::predict_with(b, &model, x, threads)
            );
        }
    }
}

#[test]
fn cpu_backend_compression_is_bitwise_the_legacy_pipeline() {
    // End-to-end pin: compressing through the backend seam must yield
    // the identical HSS operator — checked through exact matvec
    // equality on a fixed probe (f64 bit equality, not a tolerance).
    let mut rng = Rng::new(72);
    let ds = synth::blobs(300, 3, 3, 0.3, &mut rng);
    let kernel = Kernel::Gaussian { h: 1.0 };
    let params = HssParams::high_accuracy();
    let legacy = hss_svm::hss::compress::compress(&ds, &kernel, &params, 2);
    let routed = hss_svm::hss::compress::compress_with(compute::cpu(), &ds, &kernel, &params, 2);
    let probe: Vec<f64> = (0..ds.len()).map(|_| rng.gauss()).collect();
    let a = hss_svm::hss::matvec::matvec(&legacy.hss, &probe);
    let b = hss_svm::hss::matvec::matvec(&routed.hss, &probe);
    assert_eq!(a, b, "backend-routed compression changed the HSS operator");
}

#[cfg(feature = "simd-f32")]
mod simd_f32 {
    use super::*;
    use hss_svm::compute::SimdF32Backend;
    use hss_svm::svm::multiclass::train_ovo;

    fn max_rel_err(got: &[f64], want: &[f64]) -> f64 {
        assert_eq!(got.len(), want.len());
        got.iter()
            .zip(want.iter())
            .map(|(g, w)| (g - w).abs() / (1.0 + w.abs()))
            .fold(0.0, f64::max)
    }

    #[test]
    fn decision_values_within_documented_tolerance_of_f64_oracle() {
        let (model, test) = trained_model(73);
        let b = SimdF32Backend::new();
        for threads in THREAD_GRID {
            let oracle = predict::decision_function(&model, &test.x, threads);
            let fast = predict::decision_function_with(&b, &model, &test.x, threads);
            let err = max_rel_err(&fast, &oracle);
            assert!(
                err <= 1e-4,
                "simd-f32 decision error {err:e} above documented 1e-4 (threads={threads}, \
                 avx2={})",
                b.avx2_active()
            );
        }
    }

    #[test]
    fn accuracy_parity_on_synthetic_grid() {
        // The tolerance contract in terms the paper's tables use:
        // swapping the backend must not move test accuracy. Allow one
        // genuinely-boundary point (|f| ≤ 1e-4) to differ.
        let (model, test) = trained_model(74);
        let oracle = predict::decision_function(&model, &test.x, 1);
        let fast = predict::decision_function_with(&SimdF32Backend::new(), &model, &test.x, 1);
        let mut flips = 0usize;
        for (o, f) in oracle.iter().zip(fast.iter()) {
            if (*o >= 0.0) != (*f >= 0.0) {
                assert!(o.abs() <= 1e-4, "non-boundary sign flip: oracle {o:e} vs f32 {f:e}");
                flips += 1;
            }
        }
        assert!(flips <= 1, "{flips} boundary flips on a 160-point grid");
        let acc = |f: &[f64]| {
            f.iter().zip(test.y.iter()).filter(|(f, y)| (**f >= 0.0) == (**y > 0.0)).count() as f64
                / test.y.len() as f64
        };
        assert!(
            (acc(&oracle) - acc(&fast)).abs() <= 1.0 / test.y.len() as f64 + 1e-12,
            "accuracy moved: {} vs {}",
            acc(&oracle),
            acc(&fast)
        );
    }

    #[test]
    fn sparse_query_tiles_fall_back_to_f64_bitwise() {
        let (model, test) = trained_model(75);
        let xs = Points::Sparse(CsrMat::from_dense(test.x.dense()));
        let oracle = predict::decision_function(&model, &xs, 2);
        let fast = predict::decision_function_with(&SimdF32Backend::new(), &model, &xs, 2);
        // Dense model SVs + sparse tile is a sparse pairing → the
        // backend delegates to the f64 reference: exact equality.
        assert_eq!(oracle, fast);
    }

    #[test]
    fn multiclass_class_choice_is_backend_invariant_off_the_boundary() {
        let mut rng = Rng::new(76);
        let tr = synth::multiclass_blobs(300, 3, 4, 0.35, &mut rng);
        let (model, _) = train_ovo(
            &tr,
            Kernel::Gaussian { h: 1.0 },
            &HssParams::near_exact(),
            &AdmmParams { beta: 10.0, max_it: 10, relax: 1.0, tol: 0.0 },
            5.0,
            2,
        )
        .expect("ovo training");
        let te = synth::multiclass_blobs(150, 3, 4, 0.35, &mut rng);

        // Margin guard: only rows where EVERY pairwise decision clears
        // 1e-2 — an f32 perturbation (≤ ~1e-4 relative) cannot flip any
        // vote there, so class equality is a hard contract, not luck.
        let f = model.engine().decisions(&te.x, 1);
        let guarded: Vec<usize> = (0..f.rows())
            .filter(|&i| (0..f.cols()).all(|p| f[(i, p)].abs() > 1e-2))
            .collect();
        assert!(
            guarded.len() * 2 > f.rows(),
            "fixture too boundary-heavy: {}/{} rows clear the margin",
            guarded.len(),
            f.rows()
        );

        let b = SimdF32Backend::new();
        let cpu_pred = model.engine().predict_with_scores(&te.x, 2);
        let simd_pred = model.engine().predict_with_scores_with(&b, &te.x, 2);
        for &i in &guarded {
            assert_eq!(
                cpu_pred[i].0, simd_pred[i].0,
                "backend changed the predicted class on margin-guarded row {i}"
            );
        }
    }
}

#[test]
fn backend_names_and_choice_resolution() {
    assert_eq!(compute::cpu().name(), "cpu");
    let arc = compute::BackendChoice::Cpu.resolve().unwrap();
    assert_eq!(arc.name(), "cpu");
    #[cfg(feature = "simd-f32")]
    assert_eq!(compute::BackendChoice::SimdF32.resolve().unwrap().name(), "simd-f32");
    #[cfg(not(feature = "simd-f32"))]
    assert!(compute::BackendChoice::SimdF32.resolve().is_err());
    // PJRT resolution requires artifacts; without them it must fail
    // cleanly (never a panic, never a silent CPU fallback).
    std::env::set_var("HSS_SVM_ARTIFACTS", "/nonexistent-backend-oracle");
    assert!(compute::BackendChoice::Pjrt.resolve().is_err());
}
