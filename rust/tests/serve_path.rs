//! Serve-path integration tests: the micro-batched request loop must
//! survive mixed labeled/unlabeled batches (the crash the old
//! `libsvm::read`-based loop had), hit the exactly-one-batch boundary
//! correctly, and fail malformed batches without exiting.

use hss_svm::data::{CsrMat, Points};
use hss_svm::kernel::Kernel;
use hss_svm::serve::{serve_loop, BATCH};
use hss_svm::svm::{predict, SvmModel};
use hss_svm::util::prng::Rng;
use hss_svm::linalg::Mat;
use std::io::Cursor;

fn toy_model(rng: &mut Rng, n_sv: usize, dim: usize) -> SvmModel {
    SvmModel {
        sv: Mat::gauss(n_sv, dim, rng).into(),
        alpha_y: (0..n_sv).map(|_| rng.gauss()).collect(),
        bias: rng.gauss(),
        kernel: Kernel::Gaussian { h: 0.8 },
        c: 1.0,
        labels: hss_svm::data::DEFAULT_LABEL_PAIR,
    }
}

fn run(model: &SvmModel, input: &str) -> (hss_svm::serve::ServeStats, String, String) {
    let mut out = Vec::new();
    let mut err = Vec::new();
    let any = hss_svm::svm::AnyModel::Binary(model.clone());
    let stats = serve_loop(&any, None, Cursor::new(input.to_string()), &mut out, &mut err, 2)
        .expect("serve loop must not abort");
    (stats, String::from_utf8(out).unwrap(), String::from_utf8(err).unwrap())
}

/// `<i>:<v>` lines for a point with a couple of features.
fn feature_line(rng: &mut Rng, dim: usize) -> String {
    let a = 1 + rng.below(dim / 2);
    let b = a + 1 + rng.below(dim - a);
    format!("{a}:{:.3} {b}:{:.3}", rng.gauss(), rng.gauss())
}

#[test]
fn mixed_labeled_and_bare_lines_serve_fine() {
    // the original bug: {+1, −1, 0} labels in one batch = three distinct
    // classes → "not a binary dataset" killed the server on valid input
    let mut rng = Rng::new(11);
    let model = toy_model(&mut rng, 9, 6);
    let mut lines = Vec::new();
    for i in 0..40 {
        let feats = feature_line(&mut rng, 6);
        match i % 4 {
            0 => lines.push(format!("+1 {feats}")),
            1 => lines.push(format!("-1 {feats}")),
            2 => lines.push(format!("0 {feats}")),
            _ => lines.push(feats), // bare: no label at all
        }
    }
    let (stats, out, err) = run(&model, &(lines.join("\n") + "\n"));
    assert_eq!(stats.predicted, 40, "stderr: {err}");
    assert_eq!(stats.failed_batches, 0);
    let out_lines: Vec<&str> = out.lines().collect();
    assert_eq!(out_lines.len(), 40);
    for l in &out_lines {
        let mut parts = l.split_ascii_whitespace();
        let lab = parts.next().unwrap();
        assert!(lab == "+1" || lab == "-1");
        let v: f64 = parts.next().unwrap().parse().unwrap();
        assert!(v.is_finite());
    }
}

#[test]
fn served_decisions_match_decision_function() {
    let mut rng = Rng::new(12);
    let model = toy_model(&mut rng, 7, 5);
    // build points + the same lines; include an all-zero (empty) line? A
    // fully empty feature list would be a blank line (skipped), so the
    // sparsest request is a single feature.
    let rows: Vec<Vec<(usize, f64)>> =
        (0..10).map(|i| vec![(i % 5, 0.25 * (i as f64 + 1.0))]).collect();
    let x = Points::Sparse(CsrMat::from_rows(5, &rows));
    let want = predict::decision_function(&model, &x, 1);
    let input: String =
        rows.iter().map(|r| format!("{}:{}\n", r[0].0 + 1, r[0].1)).collect();
    let (stats, out, _err) = run(&model, &input);
    assert_eq!(stats.predicted, 10);
    for (l, w) in out.lines().zip(want.iter()) {
        let v: f64 = l.split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
        assert!((v - w).abs() < 1e-5, "served {v} vs direct {w}");
    }
}

#[test]
fn exact_batch_boundary_and_multi_batch() {
    let mut rng = Rng::new(13);
    let model = toy_model(&mut rng, 5, 8);
    for n in [BATCH - 1, BATCH, BATCH + 1, 2 * BATCH] {
        let input: String = (0..n).map(|_| feature_line(&mut rng, 8) + "\n").collect();
        let (stats, out, err) = run(&model, &input);
        assert_eq!(stats.predicted, n, "n={n}, stderr: {err}");
        assert_eq!(out.lines().count(), n, "n={n}");
        assert_eq!(stats.lines, n);
        let want_batches = n.div_ceil(BATCH);
        assert_eq!(stats.batches, want_batches, "n={n}");
    }
}

#[test]
fn empty_input_and_blank_lines() {
    let mut rng = Rng::new(14);
    let model = toy_model(&mut rng, 4, 4);
    let (stats, out, _) = run(&model, "");
    assert_eq!(stats, hss_svm::serve::ServeStats::default());
    assert!(out.is_empty());
    // blank and '#'-comment lines are not requests and never shift the
    // one-output-per-request alignment
    let (stats, out, _) = run(&model, "\n\n  \n# ping\n1:0.5\n# pong\n\n");
    assert_eq!(stats.predicted, 1);
    assert_eq!(stats.lines, 1);
    assert_eq!(out.lines().count(), 1);
}

#[test]
fn malformed_line_fails_its_batch_only() {
    let mut rng = Rng::new(15);
    let model = toy_model(&mut rng, 6, 6);
    // batch 1 (lines 1..=BATCH) contains two bad lines; batch 2 is clean
    let mut lines: Vec<String> = (0..BATCH).map(|_| feature_line(&mut rng, 6)).collect();
    lines[3] = "+1 2:1 2:2".to_string(); // duplicate index
    lines[10] = "+1 4:abc".to_string(); // unparseable value
    for _ in 0..5 {
        lines.push(feature_line(&mut rng, 6));
    }
    let (stats, out, err) = run(&model, &(lines.join("\n") + "\n"));
    // batch 1 dropped, batch 2 (5 lines) served
    assert_eq!(stats.batches, 2);
    assert_eq!(stats.failed_batches, 1);
    assert_eq!(stats.predicted, 5);
    assert_eq!(out.lines().count(), 5);
    // per-line errors name the offending global line numbers
    assert!(err.contains("input line 4"), "stderr: {err}");
    assert!(err.contains("input line 11"), "stderr: {err}");
    assert!(err.contains("batch dropped"), "stderr: {err}");
    // exactly the two bad lines are reported
    assert_eq!(err.lines().filter(|l| l.contains("input line")).count(), 2, "{err}");
}

#[test]
fn out_of_range_feature_index_fails_batch_not_loop() {
    let mut rng = Rng::new(16);
    let model = toy_model(&mut rng, 4, 3); // dim 3
    let input = "1:0.5\n9:1.0\n2:0.25\n";
    let (stats, out, err) = run(&model, input);
    // the over-dim line poisons its whole (single) batch
    assert_eq!(stats.failed_batches, 1);
    assert_eq!(stats.predicted, 0);
    assert!(out.is_empty());
    assert!(err.contains("input line 2"), "stderr: {err}");
}
