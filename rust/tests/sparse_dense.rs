//! Property tests pinning the sparse (CSR) data plane to the dense one:
//! `kernel_block` / `self_norms` / `decision_function` must agree to
//! ≤ 1e-12 on randomized sparse matrices, including degenerate shapes
//! (empty rows, all-zero columns, empty feature lists), and the whole
//! train→predict pipeline must run CSR end-to-end.

use hss_svm::admm::AdmmParams;
use hss_svm::data::{libsvm, scale, synth, CsrMat, Dataset, Points};
use hss_svm::hss::HssParams;
use hss_svm::kernel::{kernel_block_pts, kernel_block_pts_par, Kernel};
use hss_svm::linalg::Mat;
use hss_svm::svm::{predict, train::train_hss_svm, SvmModel};
use hss_svm::util::prng::Rng;
use hss_svm::util::testkit;

use hss_svm::util::testkit::random_csr;

#[test]
fn kernel_block_and_self_norms_agree_across_representations() {
    testkit::check("sparse-vs-dense-block", 12, |rng, _| {
        let m = 1 + rng.below(40);
        let n = 1 + rng.below(40);
        let f = 2 + rng.below(60);
        let xs = random_csr(m, f, 0.15 + 0.4 * rng.f64(), rng);
        let ys = random_csr(n, f, 0.15 + 0.4 * rng.f64(), rng);
        let xd = Points::Dense(xs.to_dense());
        let yd = Points::Dense(ys.to_dense());
        let (xs, ys) = (Points::Sparse(xs), Points::Sparse(ys));

        testkit::assert_allclose(&xs.self_norms(), &xd.self_norms(), 1e-12);
        for k in [
            Kernel::Gaussian { h: 0.6 + rng.f64() },
            Kernel::Polynomial { degree: 2, c: 1.0 },
            Kernel::Linear,
        ] {
            let want = kernel_block_pts(&k, &xd, &yd);
            for (a, b) in [(&xs, &ys), (&xs, &yd), (&xd, &ys)] {
                let got = kernel_block_pts(&k, a, b);
                testkit::assert_allclose(got.data(), want.data(), 1e-12);
            }
            let par = kernel_block_pts_par(4, &k, &xs, &ys);
            testkit::assert_allclose(par.data(), want.data(), 1e-12);
        }
    });
}

#[test]
fn decision_function_agrees_across_representations() {
    testkit::check("sparse-vs-dense-decision", 8, |rng, _| {
        let f = 3 + rng.below(40);
        let n_sv = 1 + rng.below(30);
        let n = 1 + rng.below(300); // crosses the 128-row tile boundary
        let sv = random_csr(n_sv, f, 0.3, rng);
        let x = random_csr(n, f, 0.25, rng);
        let alpha_y: Vec<f64> = (0..n_sv).map(|_| rng.gauss()).collect();
        let mk = |svp: Points| SvmModel {
            sv: svp,
            alpha_y: alpha_y.clone(),
            bias: rng_free_bias(&alpha_y),
            kernel: Kernel::Gaussian { h: 0.9 },
            c: 1.0,
            labels: hss_svm::data::DEFAULT_LABEL_PAIR,
        };
        let dense_model = mk(Points::Dense(sv.to_dense()));
        let sparse_model = mk(Points::Sparse(sv));
        let xd = Points::Dense(x.to_dense());
        let xs = Points::Sparse(x);
        let want = predict::decision_function(&dense_model, &xd, 2);
        for (m, xx) in [
            (&dense_model, &xs),
            (&sparse_model, &xd),
            (&sparse_model, &xs),
        ] {
            let got = predict::decision_function(m, xx, 2);
            testkit::assert_allclose(&got, &want, 1e-12);
        }
    });
}

/// Deterministic bias derived from the coefficients (keeps the model
/// builder closure free of a second &mut rng borrow).
fn rng_free_bias(alpha_y: &[f64]) -> f64 {
    0.25 * alpha_y.iter().sum::<f64>()
}

#[test]
fn csr_train_predict_pipeline_end_to_end() {
    // CSR from parse to model: train on a sparse dataset without any
    // densification and agree with the dense run of the same data
    let mut rng = Rng::new(31);
    let base = synth::blobs(420, 6, 4, 0.3, &mut rng);
    let sparse_all = Dataset::new(
        "blobs-csr",
        CsrMat::from_dense(base.x.dense()),
        base.y.clone(),
    );
    let (train, test) = sparse_all.split_at(300);
    assert!(train.is_sparse() && test.is_sparse());
    let (model, stats) = train_hss_svm(
        &train,
        Kernel::Gaussian { h: 1.0 },
        &HssParams::near_exact(),
        &AdmmParams { beta: 10.0, max_it: 15, relax: 1.0, tol: 0.0 },
        1.0,
        2,
    )
    .unwrap();
    assert!(model.sv.is_sparse(), "CSR training data must yield CSR SVs");
    assert!(stats.n_sv > 0);
    let acc = predict::accuracy(&model, &test, 2);
    assert!(acc > 0.8, "sparse pipeline accuracy {acc}");

    // the same model predicts identically (≤1e-12) on dense test points
    let dense_test = Dataset::new("dn", test.x.to_dense(), test.y.clone());
    let fs = predict::decision_function(&model, &test.x, 2);
    let fd = predict::decision_function(&model, &dense_test.x, 2);
    testkit::assert_allclose(&fs, &fd, 1e-12);
}

#[test]
fn libsvm_auto_load_scale_train_on_wide_sparse_file() {
    // write a wide sparse file, Auto-load it (must come back CSR), scale
    // with the implicit-zero convention, train, and stay sparse throughout
    let mut rng = Rng::new(32);
    let dim = 64usize;
    let rows: Vec<Vec<(usize, f64)>> = (0..260)
        .map(|i| {
            // class anchor feature (0 or 1) + one random noise column:
            // sparse but trivially separable
            let anchor = if i % 2 == 0 { 0 } else { 1 };
            let noise_col = 2 + rng.below(dim - 2);
            vec![(anchor, 1.0), (noise_col, 0.3 * rng.gauss())]
        })
        .collect();
    let y: Vec<f64> = (0..260).map(|i| if i % 2 == 0 { 1.0 } else { -1.0 }).collect();
    let ds = Dataset::new("wide", CsrMat::from_rows(dim, &rows), y);
    let dir = std::env::temp_dir().join(format!("hss_svm_sparse_e2e_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("wide.libsvm");
    libsvm::write_file(&ds, &path).unwrap();

    let loaded = libsvm::read_file(&path, None).unwrap();
    assert!(loaded.is_sparse(), "Auto must keep a 260x{dim} 2-nnz/row file in CSR");
    assert_eq!(loaded.x.nnz(), ds.x.nnz());

    let (mut train, mut test) = loaded.split_at(200);
    scale::scale_pair(&mut train, &mut test);
    assert!(train.is_sparse(), "scaling must preserve CSR");

    let (model, _) = train_hss_svm(
        &train,
        Kernel::Gaussian { h: 1.0 },
        &HssParams::high_accuracy(),
        &AdmmParams { beta: 10.0, max_it: 12, relax: 1.0, tol: 0.0 },
        1.0,
        2,
    )
    .unwrap();
    let acc = predict::accuracy(&model, &test, 2);
    assert!(acc > 0.9, "wide sparse file accuracy {acc}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn sparse_model_persists_and_reloads() {
    let mut rng = Rng::new(33);
    let sv = random_csr(12, 48, 0.2, &mut rng);
    let model = SvmModel {
        sv: Points::Sparse(sv),
        alpha_y: (0..12).map(|_| rng.gauss()).collect(),
        bias: 0.125,
        kernel: Kernel::Gaussian { h: 1.5 },
        c: 2.0,
        labels: hss_svm::data::DEFAULT_LABEL_PAIR,
    };
    let dir = std::env::temp_dir().join(format!("hss_svm_sp_model_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let p = dir.join("m.model");
    hss_svm::svm::persist::save(&model, &p).unwrap();
    let back = hss_svm::svm::persist::load(&p).unwrap();
    assert!(back.sv.is_sparse());
    let x = Points::Dense(Mat::gauss(40, 48, &mut rng));
    let a = predict::decision_function(&model, &x, 1);
    let b = predict::decision_function(&back, &x, 1);
    assert_eq!(a, b, "persisted sparse model must predict bit-identically");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn csr_memory_is_nnz_proportional() {
    // the tentpole's memory claim, in miniature: a 200×2000 matrix at
    // ~1% density must hold ~100× less than its dense form
    let mut rng = Rng::new(34);
    let s = random_csr(200, 2000, 0.01, &mut rng);
    let sparse_bytes = Points::Sparse(s.clone()).bytes();
    let dense_bytes = 200 * 2000 * std::mem::size_of::<f64>();
    assert!(
        sparse_bytes * 20 < dense_bytes,
        "CSR {sparse_bytes} B vs dense {dense_bytes} B"
    );
    // and round-trips exactly
    assert_eq!(CsrMat::from_dense(&s.to_dense()), s);
}
